"""Differential suite for the logical plan optimizer + redesigned compile API.

Every strategies.py case compiles each plan kind three ways — optimized
(default), ``optimize=False``, and a deliberately decorated spelling
(redundant Projects, Filter-above-Project) — and pins byte-identical
results across {xla, mlp} × {single, sharded}, against the
:mod:`repro.kernels.ref` oracle over the byte-aligned plain twin.

Beyond equality, the suite pins the optimizer's *byte* claims:

* prune-columns: a wide Project under an Aggregate strictly drops
  ``bytes_from_dram`` (the pruned plan rides the fused scalar path);
* eliminate-trivial-pred: a provably all-pass predicate leaves the union
  geometry (inert ``"none"`` lowering) — strictly fewer bus-beat bytes;
* eliminate-empty: a provably-false predicate compiles to a zero-op
  constant result;
* subsumption: covered scan requests in one ``execute_many`` batch are
  served by slicing the one covering scan (spy on ``_serve_scan``);
* cost-based join ordering: a 2-join chain probes once and orders its
  build sides by estimated cold build bytes (warm cache first).

The legacy ``compile_plan(engine, plan, path=...)`` spelling must keep
working for one release — with a ``DeprecationWarning`` — and produce
results identical to ``options=CompileOptions(...)``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import strategies
import test_compressed_execution as tce
from repro.core import (
    CompileOptions,
    RelationalMemoryEngine,
    RelationalTable,
    compile_plan,
    plan,
)
from repro.core.optimizer import optimize_trace, pred_class
from repro.core.plan import (
    Filter,
    PlanError,
    Predicate,
    Project,
    Scan,
    decompose,
)
from repro.core.planner import clear_join_build_cache
from repro.core.schema import Column, TableSchema
from repro.serve.query_server import QueryServer

I32 = np.iinfo(np.int32)


# --------------------------------------------------------------------------
# plan spellings
# --------------------------------------------------------------------------

def _logical(t: RelationalTable, kind: str, p: dict, decorated: bool):
    """The ``kind`` plan of a case — optionally in a decorated spelling the
    optimizer must canonicalize (redundant Projects, Filter above Project)."""
    b = plan(t)
    if kind == "project":
        if decorated:
            b = b.project(*t.schema.names)
        return b.project(*p["cols"])
    if kind == "filter":
        if decorated:
            return (b.project(*p["cols"])
                    .filter(p["pred_col"], p["pred_op"], p["pred_k"]))
        return (b.filter(p["pred_col"], p["pred_op"], p["pred_k"])
                .project(*p["cols"]))
    if kind == "aggregate":
        b = b.filter(p["pred_col"], p["pred_op"], p["pred_k"])
        if decorated:
            b = b.project(*t.schema.names)
        return b.sum(p["agg_col"])
    # groupby / groupby_str
    if decorated:
        b = b.project(p["group_col"], p["agg_col"])
    return b.groupby(p["group_col"], p["agg_col"], "sum", p["num_groups"])


def _assert_same(kind: str, a, b):
    if kind in ("project", "filter"):
        if isinstance(a, tuple):
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        return
    if kind == "aggregate":
        assert float(a) == float(b)
        return
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _check_oracle(kind: str, p: dict, enc_t, enc_res, plain_res, oracle):
    """Plain twin == ref oracle byte-for-byte; encoded == oracle through the
    decode-aware comparison (code words decode to the twin's values)."""
    if kind in ("project", "filter"):
        if isinstance(plain_res, tuple):
            e_pack, e_mask = enc_res
            p_pack, p_mask = plain_res
            o_pack, o_mask = oracle
            np.testing.assert_array_equal(np.asarray(p_mask),
                                          np.asarray(o_mask))
            np.testing.assert_array_equal(np.asarray(e_mask),
                                          np.asarray(o_mask))
            np.testing.assert_array_equal(np.asarray(p_pack),
                                          np.asarray(o_pack))
            tce._compare_packed(enc_t, p["cols"], e_pack, p_pack, mask=o_mask)
        else:
            np.testing.assert_array_equal(np.asarray(plain_res),
                                          np.asarray(oracle))
            tce._compare_packed(enc_t, p["cols"], enc_res, plain_res)
        return
    if kind == "aggregate":
        # compile_plan finalizes the fused [sum, count] pair to the scalar
        want = float(np.asarray(oracle)[0])
        assert float(plain_res) == want
        assert float(enc_res) == want
        return
    # groupby compiled with op "sum" finalizes to the sums row
    want = np.asarray(oracle[0])
    np.testing.assert_array_equal(np.asarray(plain_res), want)
    np.testing.assert_array_equal(np.asarray(enc_res), want)


# --------------------------------------------------------------------------
# the differential matrix
# --------------------------------------------------------------------------

CASES = (
    [("xla", None, s) for s in range(12)]
    + [("mlp", None, s) for s in range(3)]
    + [("xla", 3 + s % 2, s) for s in range(5)]
)


@pytest.mark.parametrize("revision,shards,seed", CASES)
def test_differential_optimized_vs_unoptimized(revision, shards, seed):
    """Optimized, unoptimized, and decorated spellings of every plan kind
    agree byte-for-byte with each other and the ref oracle."""
    enc_t, plain_t, ts = tce._build_twins(seed)
    enc_eng = tce._engine(revision, shards)
    plain_eng = tce._engine(revision, shards)
    for kind in strategies.PLAN_KINDS:
        p = strategies.plan_params(seed, kind)
        opts = CompileOptions(snapshot_ts=ts if p["snapshot"] else None)

        qd = _logical(enc_t, kind, p, decorated=True)
        q = _logical(enc_t, kind, p, decorated=False)
        pq = compile_plan(qd, enc_eng, options=opts)
        report = pq.explain()
        assert "route:" in report and "passes:" in report
        e_opt = pq.run()
        e_raw = compile_plan(qd, enc_eng, options=opts, optimize=False).run()
        e_std = compile_plan(q, enc_eng, options=opts, optimize=False).run()
        _assert_same(kind, e_opt, e_raw)
        _assert_same(kind, e_opt, e_std)

        p_opt = compile_plan(
            _logical(plain_t, kind, p, decorated=True), plain_eng,
            options=opts,
        ).run()
        p_raw = compile_plan(
            _logical(plain_t, kind, p, decorated=False), plain_eng,
            options=opts, optimize=False,
        ).run()
        _assert_same(kind, p_opt, p_raw)

        oracle = tce._oracle(plain_t, kind, p, ts)
        _check_oracle(kind, p, enc_t, e_opt, p_opt, oracle)


# --------------------------------------------------------------------------
# rewrite passes at the tree level
# --------------------------------------------------------------------------

def test_pushdown_and_prune_tree_shapes():
    t, _, _ = strategies.case_tables(3)
    node = plan(t).project("K", "V").filter("P", "gt", 0).build()
    out, applied = optimize_trace(node)
    assert "pushdown-filter" in applied
    assert isinstance(out, Project)
    assert isinstance(out.child, Filter)
    assert isinstance(out.child.child, Scan)

    node2 = plan(t).project(*t.schema.names).sum("V").build()
    out2, applied2 = optimize_trace(node2)
    assert "prune-columns" in applied2
    assert isinstance(out2.child, Scan)
    assert decompose(out2).columns == ("V",)


def test_normalize_pred_collapses_spellings():
    """Two value-space constants translating to the same dictionary code
    rewrite to one canonical spelling — equal shapes the engine's
    subsumption layer can then share."""
    schema = TableSchema((Column("K", "int32", codec="dict"),
                          Column("V", "int32")))
    t = RelationalTable.from_columns(schema, {
        "K": np.array([3, 12, 40, 3, 12], np.int32),
        "V": np.arange(5, dtype=np.int32),
    })
    a, applied = optimize_trace(plan(t).filter("K", "gt", 7).project("V").build())
    b, _ = optimize_trace(plan(t).filter("K", "gt", 9).project("V").build())
    assert "normalize-pred" in applied
    fa, fb = a.child, b.child
    assert isinstance(fa, Filter) and isinstance(fb, Filter)
    assert (fa.col, fa.op, fa.k) == (fb.col, fb.op, fb.k)
    assert decompose(a).pred == decompose(b).pred

    # float constants over int32 snap to the equivalent integer bound
    c, applied_f = optimize_trace(plan(t).filter("V", "gt", 3.5).build())
    assert "normalize-pred" in applied_f
    assert isinstance(c, Filter) and c.k == 3

    eng = RelationalMemoryEngine(revision="xla")
    for spelling, canonical in (((("K", "gt", 7)), ("K", "gt", 3)),
                                ((("V", "gt", 3.5)), ("V", "gt", 3))):
        col, op, k = spelling
        r1 = compile_plan(plan(t).filter(col, op, k).project("V"), eng).run()
        r2 = compile_plan(plan(t).filter(*canonical).project("V"), eng,
                          optimize=False).run()
        for x, y in zip(r1, r2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_pred_class_translated_domain():
    schema = TableSchema((Column("K", "int32", codec="dict"),
                          Column("V", "int32")))
    t = RelationalTable.from_columns(schema, {
        "K": np.array([-7, 0, 3], np.int32),
        "V": np.zeros(3, np.int32),
    })
    assert pred_class(t, Predicate("K", "gt", -8)) == "all"
    assert pred_class(t, Predicate("K", "gt", 3)) == "never"
    assert pred_class(t, Predicate("K", "lt", -7)) == "never"
    assert pred_class(t, Predicate("K", "gt", 0)) == "some"
    assert pred_class(t, Predicate("V", "gt", I32.max)) == "never"
    assert pred_class(t, Predicate("V", "lt", 5)) == "some"


# --------------------------------------------------------------------------
# byte claims: pruning, inert predicates, constant-false elimination
# --------------------------------------------------------------------------

def test_prune_columns_strictly_drops_bytes():
    """A wide Project under Sum forces the unoptimized route onto a 5-column
    materialized view; pruning rides the fused scalar path instead."""
    _, plain_t, _ = tce._build_twins(4)  # 257 rows, no churn
    q = plan(plain_t).project(*plain_t.schema.names).sum("V")

    opt_eng = RelationalMemoryEngine(revision="xla")
    pq = compile_plan(q, opt_eng)
    assert "prune-columns" in pq.passes
    assert pq.route == "fused-aggregate"
    got = pq.run()

    raw_eng = RelationalMemoryEngine(revision="xla")
    want = compile_plan(q, raw_eng, optimize=False).run()
    assert float(got) == float(want)
    assert opt_eng.stats.bytes_from_dram < raw_eng.stats.bytes_from_dram


def test_inert_pred_leaves_union_geometry():
    """A provably all-pass predicate lowers to the inert ``"none"`` spelling:
    the predicate word leaves the scan — strictly fewer bytes, same rows."""
    enc_t, _, _ = tce._build_twins(4)  # skew dict K: min value -7, no churn
    assert pred_class(enc_t, Predicate("K", "gt", -8)) == "all"
    q = plan(enc_t).filter("K", "gt", -8).project("F", "V")

    opt_eng = RelationalMemoryEngine(revision="xla")
    pq = compile_plan(q, opt_eng)
    assert "eliminate-trivial-pred" in pq.passes
    packed, mask = pq.run()
    assert bool(np.asarray(mask).all())

    raw_eng = RelationalMemoryEngine(revision="xla")
    packed_raw, mask_raw = compile_plan(q, raw_eng, optimize=False).run()
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(packed_raw))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_raw))
    assert opt_eng.stats.bytes_from_dram < raw_eng.stats.bytes_from_dram


def test_const_empty_plan_elimination():
    _, plain_t, _ = tce._build_twins(4)
    q = plan(plain_t).filter("P", "gt", I32.max).project("V")

    opt_eng = RelationalMemoryEngine(revision="xla")
    pq = compile_plan(q, opt_eng)
    assert pq.route == "const-empty"
    assert "eliminate-empty" in pq.passes
    packed, mask = pq.run()
    assert not bool(np.asarray(mask).any())
    assert not np.asarray(packed).any()

    raw_eng = RelationalMemoryEngine(revision="xla")
    packed_raw, mask_raw = compile_plan(q, raw_eng, optimize=False).run()
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(packed_raw))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_raw))
    assert opt_eng.stats.bytes_from_dram == 0
    assert raw_eng.stats.bytes_from_dram > 0

    # the scalar contract: an aggregate over a provably-false predicate is 0
    agg = compile_plan(plan(plain_t).filter("P", "gt", I32.max).sum("V"),
                       opt_eng)
    assert agg.route == "const-empty" and agg.run() == 0.0


# --------------------------------------------------------------------------
# subsumption-aware scan sharing
# --------------------------------------------------------------------------

def test_subsumption_covered_tickets_share_one_scan(monkeypatch):
    """Three projections where one covers the others: the batch serves all
    from ONE covering scan — the covered requests are sliced, not scanned.

    A wide row keeps the rme route competitive (narrow tables cost-route
    projections to the full-row fallback, which emits no scan request)."""
    rng = np.random.default_rng(11)
    schema = TableSchema(tuple(Column(f"C{i}", "int32") for i in range(24)))
    t = RelationalTable.from_columns(schema, {
        f"C{i}": rng.integers(-50, 50, 300).astype(np.int32)
        for i in range(24)
    })
    eng = RelationalMemoryEngine(revision="xla")

    groups = (("C0", "C1", "C2", "C3"),  # the covering scan
              ("C0", "C2"),
              ("C1",))
    pqs = [compile_plan(plan(t).project(*g), eng) for g in groups]
    assert all(pq.route == "rme" and len(pq.ops) == 1 for pq in pqs)

    calls = []
    orig = eng._serve_scan

    def spy(table, reqs, shared=False):
        calls.append((len(reqs), shared))
        return orig(table, reqs, shared=shared)

    monkeypatch.setattr(eng, "_serve_scan", spy)
    results = eng.execute_many([pq.ops[0] for pq in pqs])

    assert calls == [(1, True)], f"want 1 shared covering scan, saw {calls}"
    assert eng.stats.subsumed_requests == 2
    assert eng.stats.shared_scans == 1

    words = t.words()
    for pq, res, cols in zip(pqs, results, groups):
        got = np.asarray(pq.launch([res]))
        want = np.stack(
            [words[:, t.schema.word_offset(c)] for c in cols], axis=1
        )
        np.testing.assert_array_equal(got, want, err_msg=str(cols))


# --------------------------------------------------------------------------
# cost-based join ordering + build-side choice
# --------------------------------------------------------------------------

def _join_fixture(n=200, unique_probe=False, seed=42):
    rng = np.random.default_rng(seed)
    schema = TableSchema((Column("K1", "int32"), Column("K2", "int32"),
                          Column("V", "int32")))
    k1 = (rng.permutation(np.arange(n, dtype=np.int32)) if unique_probe
          else rng.integers(0, 50, n).astype(np.int32))
    probe = RelationalTable.from_columns(schema, {
        "K1": k1,
        "K2": rng.integers(0, 30, n).astype(np.int32),
        "V": rng.integers(-50, 50, n).astype(np.int32),
    })
    bk1 = np.unique(rng.integers(0, 50, 40).astype(np.int32))
    b1 = RelationalTable.from_columns(
        TableSchema((Column("K1", "int32"), Column("B1", "int32"))),
        {"K1": bk1, "B1": rng.integers(-9, 9, bk1.size).astype(np.int32)},
    )
    bk2 = np.unique(rng.integers(0, 30, 25).astype(np.int32))
    b2 = RelationalTable.from_columns(
        TableSchema((Column("K2", "int32"), Column("B2", "int32"))),
        {"K2": bk2, "B2": rng.integers(-9, 9, bk2.size).astype(np.int32)},
    )
    return probe, b1, b2


def test_multi_join_chain_matches_pairwise_joins():
    clear_join_build_cache()
    probe, b1, b2 = _join_fixture()
    eng = RelationalMemoryEngine(revision="xla")

    chain = (plan(probe).join(b1, "K1", "V", "B1")
             .join(b2, "K2", "V", "B2"))
    pq = compile_plan(chain, eng)
    assert pq.route == "device-hash-join"
    assert len(pq.join_order) == 2
    assert "join[0]:" in pq.explain()
    res = pq.run()

    ref_eng = RelationalMemoryEngine(revision="xla")
    device = CompileOptions(join_route="device-hash-join")
    ra = compile_plan(plan(probe).join(b1, "K1", "V", "B1"), ref_eng,
                      options=device).run()
    rb = compile_plan(plan(probe).join(b2, "K2", "V", "B2"), ref_eng,
                      options=device).run()
    matched = np.asarray(ra.matched) & np.asarray(rb.matched)
    v = probe.words()[:, probe.schema.word_offset("V")]

    np.testing.assert_array_equal(np.asarray(res.matched), matched)
    np.testing.assert_array_equal(np.asarray(res.s_proj),
                                  np.where(matched, v, 0))
    np.testing.assert_array_equal(np.asarray(res.r_projs[0]),
                                  np.where(matched, np.asarray(ra.r_proj), 0))
    np.testing.assert_array_equal(np.asarray(res.r_projs[1]),
                                  np.where(matched, np.asarray(rb.r_proj), 0))


def test_multi_join_orders_warm_build_first(monkeypatch):
    """A warm partition cache prices its build at 0: the chain probes it
    first even when the client spelled it second — and the chain's probe
    requests are identical, so the whole chain costs ONE physical scan."""
    clear_join_build_cache()
    probe, b1, b2 = _join_fixture()
    eng = RelationalMemoryEngine(revision="xla")

    # warm b2's device build, leave b1 cold
    compile_plan(plan(probe).join(b2, "K2", "V", "B2"), eng,
                 options=CompileOptions(join_route="device-hash-join")).run()

    chain = (plan(probe).join(b1, "K1", "V", "B1")
             .join(b2, "K2", "V", "B2"))
    pq = compile_plan(chain, eng)
    keys = [entry[0] for entry in pq.join_order]
    assert keys == ["K2", "K1"], pq.join_order
    assert pq.join_order[0][2] == 0  # warm build: estimated 0 bytes
    assert pq.join_order[1][2] > 0  # cold build carries a real estimate

    calls = []
    orig = eng._serve_scan

    def spy(table, reqs, shared=False):
        calls.append(len(reqs))
        return orig(table, reqs, shared=shared)

    monkeypatch.setattr(eng, "_serve_scan", spy)
    res = pq.run()
    # both JoinOps lowered to the same probe request over the shared union
    # view — the engine deduplicates them into one scan
    assert calls == [1], calls
    assert np.asarray(res.matched).shape == (probe.row_count,)


def test_flipped_join_route_matches_standard():
    clear_join_build_cache()
    probe, b1, _ = _join_fixture(unique_probe=True)
    q = plan(probe).join(b1, "K1", "V", "B1")

    std = compile_plan(q, RelationalMemoryEngine(revision="xla")).run()
    flip_eng = RelationalMemoryEngine(revision="xla")
    pq = compile_plan(q, flip_eng,
                      options=CompileOptions(join_route="flipped-scan-join"))
    assert pq.route == "flipped-scan-join"
    flip = pq.run()

    for field in ("s_proj", "r_proj", "matched"):
        np.testing.assert_array_equal(
            np.asarray(getattr(flip, field)),
            np.asarray(getattr(std, field)), err_msg=field)


def test_flipped_join_needs_unique_probe_keys():
    clear_join_build_cache()
    probe, b1, _ = _join_fixture(unique_probe=False)
    eng = RelationalMemoryEngine(revision="xla")
    with pytest.raises(PlanError, match="flipped"):
        compile_plan(plan(probe).join(b1, "K1", "V", "B1"), eng,
                     options=CompileOptions(join_route="flipped-scan-join"))


# --------------------------------------------------------------------------
# the compile API: CompileOptions, deprecation, explain, server passthrough
# --------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_match():
    t, _, _ = strategies.case_tables(4)
    eng = RelationalMemoryEngine(revision="xla")
    q = plan(t).sum("V")
    with pytest.warns(DeprecationWarning, match="CompileOptions"):
        legacy = compile_plan(eng, q, path="rme").run()
    new = compile_plan(q, eng, options=CompileOptions()).run()
    assert float(legacy) == float(new)

    with pytest.raises(TypeError, match="not both"):
        compile_plan(q, eng, options=CompileOptions(), path="rme")
    with pytest.raises(TypeError, match="unexpected keyword"):
        compile_plan(q, eng, no_such_option=1)
    with pytest.raises(TypeError, match="needs a plan and an engine"):
        compile_plan(q)


def test_explain_reports_trees_and_passes():
    t, _, _ = strategies.case_tables(3)
    eng = RelationalMemoryEngine(revision="xla")
    q = plan(t).project("K", "V").filter("P", "gt", 0)

    pq = compile_plan(q, eng)
    report = pq.explain()
    assert "logical:" in report and "optimized:" in report
    assert "pushdown-filter" in report

    raw = compile_plan(q, eng, optimize=False)
    assert raw.passes == ()
    assert "passes: (none)" in raw.explain()
    assert "optimized:" not in raw.explain()  # same tree, printed once

    via_options = compile_plan(q, eng, options=CompileOptions(optimize=False))
    assert via_options.passes == ()


def test_query_server_options_passthrough():
    t, _, _ = strategies.case_tables(3)
    eng = RelationalMemoryEngine(revision="xla")
    server = QueryServer(eng)
    q = plan(t).filter("P", "gt", 0).project("K", "V")

    t_opts = server.submit(q, options=CompileOptions())
    t_raw = server.submit(q, optimize=False)
    server.run_tick()
    r_opts, r_raw = t_opts.result(timeout=5), t_raw.result(timeout=5)
    for x, y in zip(r_opts, r_raw):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # options wins over the individual parameters it subsumes
    t_col = server.submit(
        plan(t).sum("V"), path="rme",
        options=CompileOptions(path="col",
                               colstore={"V": np.arange(t.row_count,
                                                        dtype=np.int32)}),
    )
    server.run_tick()
    assert t_col.result(timeout=5) == float(np.arange(t.row_count).sum())
