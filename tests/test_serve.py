"""Serving substrate: continuous-batching session over the smoke models."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ServeSession
from repro.serve.engine import Request


def test_serve_session_batched_requests():
    cfg = get_smoke_config("qwen3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = ServeSession(model, params, batch_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
                max_new=8)
        for i in range(6)  # more requests than slots: tests slot reuse
    ]
    for r in reqs:
        sess.submit(r)
    sess.run_to_completion()
    for r in reqs:
        assert r.done
        assert 1 <= len(r.out) <= 8
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def test_serve_greedy_matches_manual_decode():
    """Session output == hand-rolled prefill+decode for a single request."""
    cfg = get_smoke_config("internlm2-20b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)

    sess = ServeSession(model, params, batch_slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new=5)
    sess.submit(req)
    sess.run_to_completion()

    toks = jnp.asarray(prompt)[None, :]
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, {"tokens": toks}
    )
    out = [int(jnp.argmax(logits, -1)[0])]
    step = jax.jit(model.decode_step)
    for t in range(len(prompt), len(prompt) + 4):
        logits, cache = step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray(t, jnp.int32),
        )
        out.append(int(jnp.argmax(logits, -1)[0]))
    assert req.out == out


def test_serve_ssm_session():
    """Attention-free arch serves through the same session machinery."""
    cfg = get_smoke_config("mamba2-1.3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    sess = ServeSession(model, params, batch_slots=2, max_len=48)
    rng = np.random.default_rng(2)
    for i in range(2):
        sess.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32), max_new=6))
    sess.run_to_completion()
    assert all(r.done for r in sess.queue) or not sess.queue
