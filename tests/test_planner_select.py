"""Query planner + selection-compaction kernel tests."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import RelationalMemoryEngine, RelationalTable, TableGeometry, benchmark_schema
from repro.core.planner import execute_sum, plan_query
from repro.kernels.rme_select import densify, select_compact, select_compact_ref


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 700
    return RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )


# ---------------------------------------------------------------- planner
def test_planner_prefers_fused_for_aggregates(table):
    eng = RelationalMemoryEngine()
    plan = plan_query(eng, table, ["A1"], aggregate_only=True)
    assert plan.path == "fused"
    s, plan = execute_sum(eng, table, "A1")
    assert plan.path == "fused"
    expect = table.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(s, expect, rtol=1e-6)


def test_planner_rme_vs_row_crossover(table):
    """Low projectivity -> rme; ~full projectivity -> row (Figure 1)."""
    eng = RelationalMemoryEngine()
    low = plan_query(eng, table, ["A1", "A5"])
    assert low.path == "rme"
    high = plan_query(eng, table, [f"A{i+1}" for i in range(16)])
    assert high.path == "row"  # all columns: packed view buys nothing


def test_planner_uses_hot_cache(table):
    eng = RelationalMemoryEngine()
    cols = ("A1", "A5")
    _ = eng.register(table, cols).packed()  # warm the reorg cache
    plan = plan_query(eng, table, cols)
    assert plan.path == "hot"
    # OLTP write invalidates -> back to rme
    table.append({n: np.array([1], np.int32) for n in table.schema.names})
    plan2 = plan_query(eng, table, cols)
    assert plan2.path == "rme"


# ------------------------------------------------------- select_compact
@pytest.mark.parametrize("pred_op,k,block_rows", [
    ("gt", 0, 128), ("lt", -50, 64), ("gt", 99, 256),  # last: ~0% selectivity
])
def test_select_compact_matches_oracle(table, pred_op, k, block_rows):
    geom = TableGeometry.from_schema(table.schema, ["A1", "A9"], table.row_count)
    words = jnp.asarray(table.words())
    blocks, counts = select_compact(
        words, geom, pred_word=2, pred_op=pred_op, pred_k=k,
        block_rows=block_rows,
    )
    ref = select_compact_ref(words, geom, 2, "int32", pred_op, k)
    assert int(counts.sum()) == len(ref)
    dense = np.asarray(densify(blocks, counts, total=max(len(ref), 1)))
    if len(ref):
        np.testing.assert_array_equal(dense[: len(ref)], ref)
    # zero fill beyond counts within each block
    b = np.asarray(blocks)
    c = np.asarray(counts)
    for i in range(b.shape[0]):
        assert (b[i, c[i]:] == 0).all()


def test_select_compact_bytes_scale_with_selectivity(table):
    """The point of the kernel: shipped bytes ∝ selected rows."""
    geom = TableGeometry.from_schema(table.schema, ["A1"], table.row_count)
    words = jnp.asarray(table.words())
    _, c_all = select_compact(words, geom, pred_word=2, pred_op="gt", pred_k=-1000)
    _, c_few = select_compact(words, geom, pred_word=2, pred_op="gt", pred_k=90)
    assert int(c_all.sum()) == table.row_count
    assert int(c_few.sum()) < table.row_count // 10
