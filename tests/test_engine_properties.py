"""Hypothesis property tests split out of test_engine.py.

These need ``hypothesis`` (requirements-dev.txt); the deterministic engine
tests stay in test_engine.py so the tier-1 suite keeps its engine coverage
when hypothesis is absent.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import RelationalTable, benchmark_schema, compression


@given(st.lists(st.sampled_from(["append", "delete", "update"]),
                min_size=1, max_size=12))
@settings(max_examples=30, deadline=None)
def test_mvcc_snapshot_isolation_property(ops_seq):
    """Any interleaving of OLTP ops: old snapshots are immutable."""
    rng = np.random.default_rng(7)
    schema = benchmark_schema(32, 4)
    t = RelationalTable.from_columns(
        schema, {c.name: rng.integers(0, 10, 20).astype(np.int32)
                 for c in schema.columns}
    )
    snapshots = [(t.now(), t.to_rows())]
    for op in ops_seq:
        live = np.nonzero(t.snapshot_mask())[0]
        if op == "append":
            t.append({c.name: rng.integers(0, 10, 3).astype(np.int32)
                      for c in schema.columns})
        elif op == "delete" and len(live):
            t.delete(live[: max(1, len(live) // 4)])
        elif op == "update" and len(live):
            t.update(live[:2], {"A1": np.full(2, 77, np.int32)})
        snapshots.append((t.now(), t.to_rows()))
    for ts, expect in snapshots:
        got = t.to_rows(ts)
        for name in expect:
            np.testing.assert_array_equal(got[name], expect[name])


@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=500))
@settings(max_examples=50, deadline=None)
def test_dict_codec_roundtrip_property(values):
    vals = np.asarray(values, dtype=np.int64)
    codec = compression.DictCodec.fit(vals)
    codes = codec.encode(vals)
    np.testing.assert_array_equal(np.asarray(codec.decode(jnp.asarray(codes))), vals)
    assert codes.dtype == np.int32


@given(st.lists(st.integers(0, 1 << 30), min_size=1, max_size=500),
       st.sampled_from([16, 128, 1024]))
@settings(max_examples=50, deadline=None)
def test_delta_codec_roundtrip_property(values, frame):
    vals = np.asarray(values, dtype=np.int64)
    codec = compression.DeltaCodec.fit(vals, frame)
    out = np.asarray(codec.decode(jnp.asarray(codec.encode(vals))))
    np.testing.assert_array_equal(out, vals)
