"""Differential property harness for compressed execution (paper §4).

Every generated case runs the same logical plans three ways —

* **encoded**: dict/FOR/string codecs attached, kernels on raw code words,
  predicate constants translated at compile time, decode only on finalize;
* **plain twin**: the identical word layout with no codecs (strings stored
  as their raw dictionary codes, so the twin is byte-aligned word-for-word);
* **oracle**: :mod:`repro.kernels.ref` over the plain twin's storage words —

and asserts the three agree byte-for-byte, across {xla, mlp} × {single,
sharded} backends, with and without MVCC snapshots, through dictionary
re-fits forced by out-of-dictionary appends.  Encoded ``bytes_from_dram``
must never exceed the plain twin's for the same tick.

Cases are deterministic seeded-numpy generators (``tests/strategies.py``) —
``hypothesis`` is a CI-only extra, and the tier-1 suite must carry the full
harness everywhere.  ``test_case_count_floor`` pins the generated-case
census at >= 200.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from repro.core import RelationalMemoryEngine, RelationalTable
from repro.core.compression import DeltaCodec, DictCodec
from repro.core.distributed import ShardedEngine
from repro.core.plan import plan
from repro.core.requests import (
    AggregateOp,
    FilterOp,
    GroupByOp,
    JoinOp,
    ProjectOp,
)
from repro.core.schema import TableGeometry
from repro.kernels import ref
from repro.serve.query_server import QueryServer

I32 = np.iinfo(np.int32)


# --------------------------------------------------------------------------
# case construction: encoded table + byte-aligned plain twin + churn
# --------------------------------------------------------------------------

def _churn_columns(rng: np.random.Generator) -> dict[str, np.ndarray]:
    """Post-build writes, hostile on purpose: ``K`` mixes novel values (dict
    re-fit), ``F`` can dip below the fitted base (FOR re-fit), ``S`` draws
    from the full pool (string-dict re-fit)."""
    m = int(rng.integers(1, 33))
    return {
        "K": rng.integers(-2000, 2000, m).astype(np.int32),
        "F": rng.integers(-200, 200, m).astype(np.int32),
        "S": rng.choice(strategies.STRING_POOL, m),
        "V": rng.integers(-50, 50, m).astype(np.int32),
        "P": rng.integers(-50, 50, m).astype(np.int32),
    }


def _with_str_codes(cols: dict, sdict: DictCodec) -> dict:
    """The plain twin's spelling of ``cols``: ``S`` as final-dictionary codes.

    The encoded table's own codes always land on the *final* (post-churn)
    dictionary too — any novel string raises at encode and forces the merge
    re-fit, and the merged dictionary is exactly the union fit — so the two
    tables stay code-identical without the twin ever seeing a codec."""
    s = cols["S"]
    codes = sdict.encode(s) if s.size else np.zeros(0, np.int32)
    return dict(cols, S=codes)


def _build_twins(seed: int):
    """(encoded, plain twin, snapshot_ts-or-None).

    Odd seeds churn: append (re-fitting all three codecs), delete, capture
    the snapshot time, then append more — so snapshot plans must mask the
    late rows and no-snapshot plans must see every physical version."""
    init = strategies.logical_columns(seed)
    with_churn = seed % 2 == 1
    rng = np.random.default_rng(seed + 999)
    churn_a = _churn_columns(rng) if with_churn else None
    churn_b = _churn_columns(rng) if with_churn else None
    all_s = np.concatenate(
        [c["S"] for c in (init, churn_a, churn_b) if c is not None]
    )
    sdict = DictCodec.fit(all_s)

    enc = RelationalTable.from_columns(strategies.ENC_SCHEMA, init)
    plain = RelationalTable.from_columns(
        strategies.PLAIN_SCHEMA, _with_str_codes(init, sdict)
    )
    if not with_churn:
        return enc, plain, enc.now()

    enc.append(churn_a)
    plain.append(_with_str_codes(churn_a, sdict))
    if enc.row_count > 2:
        dead = np.unique(rng.integers(0, enc.row_count, 3))
        enc.delete(dead)
        plain.delete(dead)
    ts = enc.now()
    assert plain.now() == ts, "twin MVCC clocks diverged"
    enc.append(churn_b)
    plain.append(_with_str_codes(churn_b, sdict))
    return enc, plain, ts


def _make_ops(engine, t: RelationalTable, kind: str, params: dict, ts):
    ts = ts if params["snapshot"] else None
    if kind == "project":
        view = engine.register(t, params["cols"], snapshot_ts=ts)
        if ts is None:
            return ProjectOp(view)
        # snapshot projection = the planner's inert-predicate filter spelling
        return FilterOp(view, params["cols"][0], "none", 0, snapshot_ts=ts)
    if kind == "filter":
        view = engine.register(t, params["cols"], snapshot_ts=ts)
        return FilterOp(view, params["pred_col"], params["pred_op"],
                        params["pred_k"], snapshot_ts=ts)
    if kind == "aggregate":
        return AggregateOp(t, params["agg_col"], pred_col=params["pred_col"],
                           pred_op=params["pred_op"], pred_k=params["pred_k"],
                           snapshot_ts=ts)
    # groupby / groupby_str
    return GroupByOp(t, params["group_col"], params["agg_col"],
                     params["num_groups"], snapshot_ts=ts)


# --------------------------------------------------------------------------
# oracle + three-way comparison
# --------------------------------------------------------------------------

def _oracle(plain: RelationalTable, kind: str, params: dict, ts):
    """The :mod:`repro.kernels.ref` ground truth over the twin's storage."""
    words = jnp.asarray(plain.words())
    schema = plain.schema
    valid = (ref.mvcc_mask_ref(words, plain.ts_begin_word, ts)
             if params["snapshot"] else None)
    if kind == "project":
        geom = TableGeometry.from_schema(schema, params["cols"],
                                         row_count=plain.row_count)
        if not params["snapshot"]:
            return ref.project_ref(words, geom)
        return ref.filter_project_ref(
            words, geom, schema.word_offset(params["cols"][0]), "int32",
            "none", 0, valid=valid)
    if kind == "filter":
        geom = TableGeometry.from_schema(schema, params["cols"],
                                         row_count=plain.row_count)
        return ref.filter_project_ref(
            words, geom, schema.word_offset(params["pred_col"]), "int32",
            params["pred_op"], params["pred_k"], valid=valid)
    if kind == "aggregate":
        s = ref.aggregate_ref(
            words, schema.word_offset(params["agg_col"]), "int32",
            schema.word_offset(params["pred_col"]), "int32",
            params["pred_op"], params["pred_k"], valid=valid)
        # count via a 1-group group-by (group_ids(x, 1) == 0 everywhere)
        _, counts = ref.groupby_sum_ref(
            words, schema.word_offset(params["pred_col"]),
            schema.word_offset(params["agg_col"]), "int32", 1,
            pred_word=schema.word_offset(params["pred_col"]),
            pred_op=params["pred_op"], pred_k=params["pred_k"], valid=valid)
        return jnp.stack([s, counts[0]])
    return ref.groupby_sum_ref(
        words, schema.word_offset(params["group_col"]),
        schema.word_offset(params["agg_col"]), "int32",
        params["num_groups"], valid=valid)


def _compare_packed(enc_t, cols, enc_packed, plain_packed, mask=None):
    """Encoded packed blocks carry raw code words; failing/invisible rows are
    zeroed with code 0, which *decodes* to a real value — so codec columns
    compare decoded on mask-true rows and as literal zeros elsewhere, while
    plain columns compare byte-for-byte."""
    ep, pp = np.asarray(enc_packed), np.asarray(plain_packed)
    assert ep.shape == pp.shape
    sel = (np.ones(len(ep), bool) if mask is None
           else np.asarray(mask).astype(bool))
    ordered = sorted(cols, key=enc_t.schema.byte_offset)
    for j, name in enumerate(ordered):
        e_col, p_col = ep[:, j], pp[:, j]
        codec = enc_t.codecs.get(name)
        if codec is None:
            np.testing.assert_array_equal(e_col, p_col, err_msg=name)
            continue
        np.testing.assert_array_equal(e_col[~sel], 0, err_msg=name)
        if isinstance(codec, DictCodec) and codec.dictionary.dtype.kind in (
                "U", "S", "O"):
            # the twin stores the same final-dictionary codes (see
            # _with_str_codes): code equality == decoded equality
            np.testing.assert_array_equal(e_col[sel], p_col[sel],
                                          err_msg=name)
            continue
        dec = codec.decode_np(e_col[sel], np.flatnonzero(sel))
        np.testing.assert_array_equal(dec, p_col[sel], err_msg=name)


def _check_case(enc_t, kind, params, enc_res, plain_res, oracle_res):
    if kind in ("project", "filter"):
        if isinstance(plain_res, tuple):  # filter contract: (packed, mask)
            e_pack, e_mask = enc_res
            p_pack, p_mask = plain_res
            o_pack, o_mask = oracle_res
            np.testing.assert_array_equal(np.asarray(e_mask),
                                          np.asarray(o_mask))
            np.testing.assert_array_equal(np.asarray(p_mask),
                                          np.asarray(o_mask))
            np.testing.assert_array_equal(np.asarray(p_pack),
                                          np.asarray(o_pack))
            _compare_packed(enc_t, params["cols"], e_pack, p_pack,
                            mask=o_mask)
        else:
            np.testing.assert_array_equal(np.asarray(plain_res),
                                          np.asarray(oracle_res))
            _compare_packed(enc_t, params["cols"], enc_res, plain_res)
        return
    if kind == "aggregate":
        np.testing.assert_array_equal(np.asarray(enc_res),
                                      np.asarray(oracle_res))
        np.testing.assert_array_equal(np.asarray(plain_res),
                                      np.asarray(oracle_res))
        return
    # group-by: (sums, counts) on every path, byte-equal across all three
    for got in (enc_res, plain_res):
        for g, o in zip(got, oracle_res):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(o))


# --------------------------------------------------------------------------
# the differential suite
# --------------------------------------------------------------------------

def _engine(revision, shards):
    if shards is None:
        return RelationalMemoryEngine(revision=revision)
    return ShardedEngine(num_shards=shards, revision=revision)


SINGLE_XLA_SEEDS = tuple(range(24))
SINGLE_MLP_SEEDS = tuple(range(6))
SHARDED_SEEDS = tuple(range(12))

CASES = (
    [("xla", None, s) for s in SINGLE_XLA_SEEDS]
    + [("mlp", None, s) for s in SINGLE_MLP_SEEDS]
    + [("xla", 3 + s % 2, s) for s in SHARDED_SEEDS]
)

JOIN_CASES = (
    [("xla", None, s) for s in range(12)]
    + [("mlp", None, s) for s in range(4)]
    + [("xla", 3 + s % 2, s) for s in range(6)]
)


def test_case_count_floor():
    """The CI contract: >= 200 generated (table, plan) cases."""
    n = len(CASES) * len(strategies.PLAN_KINDS) + len(JOIN_CASES)
    assert n >= 200, n


@pytest.mark.parametrize("revision,shards,seed", CASES)
def test_differential_mixed_tick(revision, shards, seed):
    """One coalesced tick of all plan kinds: encoded == plain twin ==
    ref oracle, and encoded DRAM traffic never exceeds the twin's."""
    kinds = strategies.PLAN_KINDS
    all_params = {k: strategies.plan_params(seed, k) for k in kinds}

    enc_eng, plain_eng = _engine(revision, shards), _engine(revision, shards)
    enc_t, plain_t, ts = _build_twins(seed)
    enc_res = enc_eng.execute_many(
        [_make_ops(enc_eng, enc_t, k, all_params[k], ts) for k in kinds])
    plain_res = plain_eng.execute_many(
        [_make_ops(plain_eng, plain_t, k, all_params[k], ts) for k in kinds])

    for i, kind in enumerate(kinds):
        oracle_res = _oracle(plain_t, kind, all_params[kind], ts)
        _check_case(enc_t, kind, all_params[kind], enc_res[i], plain_res[i],
                    oracle_res)

    assert enc_eng.stats.bytes_from_dram <= plain_eng.stats.bytes_from_dram
    assert enc_eng.stats.bytes_saved_compression >= 0


@pytest.mark.parametrize("revision,shards,seed", JOIN_CASES)
def test_differential_join(revision, shards, seed):
    """Encoded equi-joins on one shared table-level dictionary: raw-code
    probe == plain-value probe == sort-probe oracle, snapshot included."""
    (enc_p, enc_b), (plain_p, plain_b), _ = strategies.build_tables(seed)
    snapshot = seed % 2 == 1
    ts = None
    if snapshot:
        ts = enc_p.now()
        assert plain_p.now() == ts
        # post-snapshot probe rows use in-dictionary keys only (an encoded
        # join key may not re-fit away from its shared dictionary)
        rng = np.random.default_rng(seed + 777)
        pool = enc_p.codecs["K"].dictionary.astype(np.int32)
        extra = {
            "K": rng.choice(pool, 9),
            "F": rng.integers(0, 100, 9).astype(np.int32),
            "S": rng.choice(strategies.STRING_POOL, 9),
            "V": rng.integers(-50, 50, 9).astype(np.int32),
            "P": rng.integers(-50, 50, 9).astype(np.int32),
        }
        enc_p.append(extra)
        plain_p.append(dict(extra, S=strategies.str_codes(extra["S"])))

    def run(eng, probe, build):
        op = JoinOp(eng.register(probe, ("V", "K"), snapshot_ts=ts),
                    "V", "K", build, "B", snapshot_ts=ts)
        return eng.execute_many([op])[0]

    enc_eng, plain_eng = _engine(revision, shards), _engine(revision, shards)
    enc_res = run(enc_eng, enc_p, enc_b)
    plain_res = run(plain_eng, plain_p, plain_b)

    pw = jnp.asarray(plain_p.words())
    s_valid = (ref.mvcc_mask_ref(pw, plain_p.ts_begin_word, ts)
               if ts is not None else None)
    kw = plain_p.schema.word_offset("K")
    vw = plain_p.schema.word_offset("V")
    bw = jnp.asarray(plain_b.words())
    o_s, o_r, o_m = ref.hash_join_ref(
        pw[:, kw], pw[:, vw],
        bw[:, plain_b.schema.word_offset("K")],
        bw[:, plain_b.schema.word_offset("B")],
        s_valid=s_valid)

    for got in (enc_res, plain_res):
        np.testing.assert_array_equal(np.asarray(got.s_proj), np.asarray(o_s))
        np.testing.assert_array_equal(np.asarray(got.r_proj), np.asarray(o_r))
        np.testing.assert_array_equal(np.asarray(got.matched),
                                      np.asarray(o_m))
    assert enc_eng.stats.bytes_from_dram <= plain_eng.stats.bytes_from_dram


# --------------------------------------------------------------------------
# zero decode in the fused pass
# --------------------------------------------------------------------------

def test_zero_decodes_in_fused_pass(monkeypatch):
    """A mixed tick over encoded columns — filter, group-by (int and string
    keys), FOR aggregate, shared-dictionary join — never calls a codec
    decode; the first client *read* does."""
    (enc_p, enc_b), _, _ = strategies.build_tables(9)
    eng = RelationalMemoryEngine(revision="xla")

    # patch only after ingest: the declared-codec first-append re-fit is
    # allowed to decode (it rewrites stored words); the *scan* is not
    calls = {"n": 0}
    for cls, name in ((DictCodec, "decode"), (DictCodec, "decode_np"),
                      (DeltaCodec, "decode"), (DeltaCodec, "decode_np")):
        orig = getattr(cls, name)

        def counting(self, *a, _orig=orig, **kw):
            calls["n"] += 1
            return _orig(self, *a, **kw)

        monkeypatch.setattr(cls, name, counting)

    view = eng.register(enc_p, ("K", "V"))
    ops = [
        FilterOp(view, "K", "gt", 0),
        AggregateOp(enc_p, "F", pred_col="K", pred_op="lt", pred_k=3),
        GroupByOp(enc_p, "K", "V", 16),
        GroupByOp(enc_p, "S", "V", len(strategies.STRING_POOL)),
        JoinOp(eng.register(enc_p, ("V", "K")), "V", "K", enc_b, "B"),
    ]
    results = eng.execute_many(ops)
    for r in results:
        for part in (r if isinstance(r, tuple) else (r,)):
            np.asarray(getattr(part, "s_proj", part))
    assert calls["n"] == 0, "fused pass decoded an encoded column"

    # ...and decode-on-finalize fires exactly when a client reads back
    _ = view.column("K")
    assert calls["n"] == 1
    assert eng.stats.decodes == 1
    _ = view.column("K")
    assert calls["n"] == 1, "second read must hit the decode cache"
    assert eng.stats.decode_cache_hits == 1


# --------------------------------------------------------------------------
# strings end-to-end through the QueryServer
# --------------------------------------------------------------------------

def test_string_column_through_query_server_mixed_tick():
    """A string column flows through a QueryServer mixed tick — filter on a
    string predicate, string group-by, shared-dict join — in exactly one
    shared scan, byte-identical to the host oracle."""
    (enc_p, enc_b), _, (logical, build) = strategies.build_tables(21)
    eng = RelationalMemoryEngine(revision="xla")
    server = QueryServer(eng)

    n_groups = len(strategies.STRING_POOL)
    t_filter = server.submit(plan(enc_p).filter("S", "gt", "cedar")
                             .project("S", "V"))
    t_gb = server.submit(plan(enc_p).groupby("S", "V", "sum", n_groups))
    t_join = server.submit(plan(enc_p).join(enc_b, "K", "V", "B"))
    server.run_tick()
    scans = eng.stats.shared_scans
    assert scans == 1, f"mixed tick took {scans} scans, want 1"

    s, v, k = logical["S"], logical["V"], logical["K"]
    sdict = enc_p.codecs["S"]

    packed, mask = t_filter.result(timeout=5)
    np.testing.assert_array_equal(np.asarray(mask), s > "cedar")
    live = np.asarray(mask).astype(bool)
    codes = np.asarray(packed)[:, 0]
    np.testing.assert_array_equal(sdict.decode_np(codes[live]), s[live])
    np.testing.assert_array_equal(np.asarray(packed)[live, 1], v[live])

    sums = np.asarray(t_gb.result(timeout=5))
    want = np.zeros(n_groups, np.float32)
    for code, val in zip(sdict.encode(s), v):
        want[code] += val
    np.testing.assert_array_equal(sums, want)

    jr = t_join.result(timeout=5)
    bk, bv = build["K"], build["B"]
    o_s, o_r, o_m = ref.hash_join_ref(
        jnp.asarray(k), jnp.asarray(v), jnp.asarray(bk), jnp.asarray(bv))
    np.testing.assert_array_equal(np.asarray(jr.s_proj), np.asarray(o_s))
    np.testing.assert_array_equal(np.asarray(jr.r_proj), np.asarray(o_r))
    np.testing.assert_array_equal(np.asarray(jr.matched), np.asarray(o_m))

    snap = server.snapshot()
    assert snap["engine_bytes_saved_compression"] > 0
    assert "engine_decodes" in snap and "engine_decode_cache_hits" in snap


# --------------------------------------------------------------------------
# codec edge-case regressions
# --------------------------------------------------------------------------

class TestDictCodecEdges:
    def test_empty_fit_serves_empty_and_rejects_values(self):
        c = DictCodec.fit(np.zeros(0, np.int32))
        assert c.code_bits == 0 and c.code_bytes == 0
        assert c.encode(np.zeros(0, np.int32)).size == 0
        with pytest.raises(ValueError, match="outside the fitted dictionary"):
            c.encode(np.array([1], np.int32))

    def test_single_value_dictionary_is_zero_bits(self):
        c = DictCodec.fit(np.array([42, 42, 42], np.int32))
        assert c.code_bits == 0 and c.code_bytes == 0
        np.testing.assert_array_equal(
            c.encode(np.array([42, 42], np.int32)), [0, 0])
        # translated predicates still classify correctly on the 0-bit domain
        assert c.translate_pred("gt", 41) == ("gt", -1)  # every code passes
        assert c.translate_pred("gt", 42) == ("gt", 0)  # none pass
        assert c.translate_pred("lt", 42) == ("lt", 0)  # none pass
        assert c.translate_pred("lt", 43) == ("lt", 1)  # every code passes

    def test_int32_extreme_values_roundtrip(self):
        vals = np.array([I32.min, -1, 0, I32.max], np.int32)
        c = DictCodec.fit(vals)
        np.testing.assert_array_equal(c.decode_np(c.encode(vals)), vals)
        assert c.translate_pred("gt", I32.max)[1] == c.dictionary.size - 1
        assert c.translate_pred("lt", I32.min)[1] == 0

    def test_out_of_dictionary_encode_raises(self):
        c = DictCodec.fit(np.array([1, 5, 9], np.int32))
        with pytest.raises(ValueError, match="outside the fitted dictionary"):
            c.encode(np.array([1, 7], np.int32))


class TestDeltaCodecEdges:
    def test_int32_min_reference(self):
        vals = np.array([I32.min, I32.min + 5, I32.min + 1], np.int32)
        c = DeltaCodec.fit_global(vals)
        assert c.base == I32.min
        np.testing.assert_array_equal(c.encode(vals), [0, 5, 1])
        np.testing.assert_array_equal(c.decode_np(c.encode(vals)), vals)
        # bound arithmetic is int64: k - base overflows int32 but collapses
        assert c.translate_pred("gt", 0) == ("gt", I32.max)  # never pass
        # k == base: no delta is negative, so ("lt", 0) never passes
        assert c.translate_pred("lt", I32.min) == ("lt", 0)

    def test_full_range_delta_overflows_honestly(self):
        c = DeltaCodec.fit_global(np.array([I32.min], np.int32))
        with pytest.raises(ValueError, match="delta overflows int32"):
            c.encode(np.array([I32.max], np.int32))

    def test_fitted_width_claim_enforced_on_encode(self):
        c = DeltaCodec.fit_global(np.array([100, 110], np.int32))
        assert c.code_bits == 4
        with pytest.raises(ValueError, match="outside the fitted delta"):
            c.encode(np.array([90], np.int32))  # below the reference
        with pytest.raises(ValueError, match="outside the fitted delta"):
            c.encode(np.array([100 + 16], np.int32))  # above the claim

    def test_short_tail_frames_roundtrip(self):
        rng = np.random.default_rng(5)
        vals = (rng.integers(-1000, 1000, 37)).astype(np.int32)
        c = DeltaCodec.fit(vals, frame_rows=16)
        assert len(c.references) == 3 and not c.single_frame
        np.testing.assert_array_equal(c.decode_np(c.encode(vals)), vals)
        rows = np.array([0, 16, 36])
        np.testing.assert_array_equal(
            c.decode_np(c.encode(vals)[rows], rows), vals[rows])
        with pytest.raises(ValueError, match="single-frame"):
            c.translate_pred("gt", 0)

    def test_empty_fit_global(self):
        c = DeltaCodec.fit_global(np.zeros(0, np.int32))
        assert c.base == 0 and c.code_bits == 0 and c.single_frame
        assert c.encode(np.zeros(0, np.int32)).size == 0


class TestTableRefitHonesty:
    """Out-of-dictionary writes must re-fit (rewriting stored code words and
    bumping the storage epoch so device mirrors and caches resync) or drop
    the codec — never serve stale codes."""

    def _dict_table(self):
        schema = strategies.ENC_SCHEMA
        cols = {
            "K": np.array([3, 7, 3], np.int32),
            "F": np.array([10, 11, 12], np.int32),
            "S": np.array(["fig", "iris", "fig"]),
            "V": np.arange(3, dtype=np.int32),
            "P": np.arange(3, dtype=np.int32),
        }
        return RelationalTable.from_columns(schema, cols)

    def test_append_outside_dictionary_refits(self):
        t = self._dict_table()
        epoch0 = t.storage_epoch
        old_codes = t.words()[:, 0].copy()
        t.append({"K": np.array([5], np.int32),
                  "F": np.array([13], np.int32),
                  "S": np.array(["amber"]),
                  "V": np.array([3], np.int32),
                  "P": np.array([3], np.int32)})
        assert t.storage_epoch > epoch0
        np.testing.assert_array_equal(
            t.codecs["K"].dictionary.astype(np.int64), [3, 5, 7])
        # stored code words were rewritten under the merged dictionary
        assert not np.array_equal(t.words()[:3, 0], old_codes)
        np.testing.assert_array_equal(
            t.codecs["K"].decode_np(t.words()[:4, 0]), [3, 7, 3, 5])
        np.testing.assert_array_equal(
            t.codecs["S"].decode_np(t.words()[:4, 2]),
            ["fig", "iris", "fig", "amber"])

    def test_update_outside_dictionary_refits(self):
        t = self._dict_table()
        epoch0 = t.storage_epoch
        t.update(np.array([1]), {"K": np.array([-9], np.int32)})
        assert t.storage_epoch > epoch0
        np.testing.assert_array_equal(
            t.codecs["K"].dictionary.astype(np.int64), [-9, 3, 7])
        # the MVCC-visible column reads back the merged-dictionary values
        np.testing.assert_array_equal(np.sort(t.read_column("K")), [-9, 3, 3])

    def test_for_overflow_drops_codec_to_plain(self):
        schema = strategies.ENC_SCHEMA
        t = RelationalTable.from_columns(schema, {
            "K": np.array([1], np.int32),
            "F": np.array([I32.min], np.int32),
            "S": np.array(["fig"]),
            "V": np.array([0], np.int32),
            "P": np.array([0], np.int32),
        })
        assert "F" in t.codecs
        t.append({"K": np.array([1], np.int32),
                  "F": np.array([I32.max], np.int32),
                  "S": np.array(["fig"]),
                  "V": np.array([0], np.int32),
                  "P": np.array([0], np.int32)})
        assert "F" not in t.codecs  # dropped honestly, values stay plain
        np.testing.assert_array_equal(t.words()[:2, 1],
                                      [I32.min, I32.max])

    def test_refit_resyncs_device_and_invalidates_caches(self):
        eng = RelationalMemoryEngine(revision="xla")
        t = self._dict_table()
        view = eng.register(t, ("K", "V"))
        before = np.asarray(view.packed()).copy()
        k0 = np.asarray(view.column("K"))
        t.append({"K": np.array([4], np.int32),
                  "F": np.array([13], np.int32),
                  "S": np.array(["cedar"]),
                  "V": np.array([9], np.int32),
                  "P": np.array([9], np.int32)})
        view2 = eng.register(t, ("K", "V"))
        after = np.asarray(view2.packed())
        # the re-encoded prefix reached the device (full resync, not a
        # stale-code tail merge)
        np.testing.assert_array_equal(
            t.codecs["K"].decode_np(after[:, 0]), [3, 7, 3, 4])
        assert not np.array_equal(after[:3], before)
        np.testing.assert_array_equal(np.asarray(view2.column("K")),
                                      np.concatenate([k0, [4]]))

    def test_mismatched_dictionaries_fall_back_to_decode_join(self):
        """Independently fitted key dictionaries can't join on raw codes —
        the shared-scan route decodes the key column (the one honest decode)
        and must still match the oracle."""
        rng = np.random.default_rng(3)
        left_k = rng.integers(-20, 20, 64).astype(np.int32)
        left_v = rng.integers(-50, 50, 64).astype(np.int32)
        right_k = np.unique(rng.integers(-20, 20, 30).astype(np.int32))
        right_b = rng.integers(-50, 50, right_k.size).astype(np.int32)
        schema = strategies.ENC_SCHEMA
        left = RelationalTable.from_columns(schema, {
            "K": left_k, "F": np.zeros(64, np.int32),
            "S": np.repeat(np.array(["fig"]), 64),
            "V": left_v, "P": np.zeros(64, np.int32)})
        from repro.core.schema import Column, TableSchema
        rschema = TableSchema((Column("K", "int32", codec="dict"),
                               Column("B", "int32")))
        right = RelationalTable.from_columns(
            rschema, {"K": right_k, "B": right_b})
        assert not np.array_equal(left.codecs["K"].dictionary,
                                  right.codecs["K"].dictionary)
        eng = RelationalMemoryEngine(revision="xla")
        # the device route refuses mismatched dictionaries outright...
        with pytest.raises(ValueError, match="shared table-level dictionary"):
            JoinOp(eng.register(left, ("V", "K")), "V", "K",
                   right, "B").lower()
        # ...and the planner falls back to the host sort-probe route
        server = QueryServer(eng)
        ticket = server.submit(plan(left).join(right, "K", "V", "B"))
        server.run_tick()
        assert ticket.route == "shared-scan-join"
        res = ticket.result(timeout=5)
        o_s, o_r, o_m = ref.hash_join_ref(
            jnp.asarray(left_k), jnp.asarray(left_v),
            jnp.asarray(right_k), jnp.asarray(right_b))
        np.testing.assert_array_equal(np.asarray(res.s_proj),
                                      np.asarray(o_s))
        np.testing.assert_array_equal(np.asarray(res.r_proj),
                                      np.asarray(o_r))
        np.testing.assert_array_equal(np.asarray(res.matched),
                                      np.asarray(o_m))


class TestLoweringGuards:
    def test_dict_encoded_aggregate_rejected(self):
        t, _, _ = strategies.case_tables(8)
        with pytest.raises(ValueError, match="ranks, not"):
            AggregateOp(t, "K").lower()

    def test_string_groupby_needs_dictionary_coverage(self):
        t, _, _ = strategies.case_tables(9)
        n = t.codecs["S"].dictionary.size
        with pytest.raises(ValueError, match="cannot cover"):
            GroupByOp(t, "S", "V", n - 1).lower()

    def test_for_group_key_rejected(self):
        t, _, _ = strategies.case_tables(9)
        with pytest.raises(ValueError, match="dict codec"):
            GroupByOp(t, "F", "V", 8).lower()

    def test_encoded_join_payload_rejected(self):
        (enc_p, enc_b), _, _ = strategies.build_tables(9)
        eng = RelationalMemoryEngine(revision="xla")
        with pytest.raises(ValueError, match="payload"):
            JoinOp(eng.register(enc_p, ("F", "K")), "F", "K",
                   enc_b, "B").lower()
