"""Write-path HTAP: delta-chunked uploads, delta-aware view caching, snapshot
isolation under concurrent writes, and live writes through the QueryServer.

The contract under test (ISSUE 4 acceptance criteria):

* appending N rows to a T-row resident table uploads O(N) bytes — exact
  byte accounting via ``EngineStats.bytes_uploaded_delta``;
* deletes/updates upload only the patched hidden ``__ts_end`` words;
* a hot ``ReorgCache`` view survives an append and is served by a
  tail-chunk delta scan whose result equals a cold full materialization —
  for every op kind;
* a reader holding snapshot ``ts`` gets byte-identical results before and
  after concurrent append/update/delete;
* the ``QueryServer`` admits insert/update/delete tickets interleaved with
  reads: writes apply first, reads see the tick's post-write snapshot.
"""

import numpy as np
import pytest

from repro.core import (
    AggregateOp,
    FilterOp,
    GroupByOp,
    ProjectOp,
    RelationalMemoryEngine,
    RelationalTable,
    WORD,
    benchmark_schema,
    plan,
)
from repro.core.plan import PlanError
from repro.core.planner import compile_plan
from repro.core.table import MAX_PATCH_EVENTS
from repro.serve import QueryServer

ROW_BYTES = 64


def make_table(n=500, seed=0):
    rng = np.random.default_rng(seed)
    schema = benchmark_schema(ROW_BYTES, 4)
    cols = {c.name: rng.integers(-100, 100, n).astype(np.int32)
            for c in schema.columns}
    return schema, RelationalTable.from_columns(schema, cols)


def fresh_rows(schema, n, fill=7):
    return {c.name: np.full(n, fill, np.int32) for c in schema.columns}


# ------------------------------------------------------- table-level deltas
def test_version_split_append_vs_mutation():
    schema, t = make_table(100)
    w0, m0 = t.append_watermark, t.mutation_version
    t.append(fresh_rows(schema, 3))
    assert t.append_watermark == w0 + 3 and t.mutation_version == m0
    t.delete(np.array([0, 1]))
    assert t.append_watermark == w0 + 3 and t.mutation_version == m0 + 1
    assert t.version == (w0 + 3, m0 + 1)
    # deleting already-dead rows is a no-op, not a new mutation event
    t.delete(np.array([0, 1]))
    assert t.mutation_version == m0 + 1
    # update = one delete event + an append of the replacements
    t.update(np.array([2]), {"A1": np.array([42], np.int32)})
    assert t.version == (w0 + 4, m0 + 2)


def test_patch_log_records_touched_rows():
    schema, t = make_table(50)
    seq = t.mutation_version
    t.delete(np.array([3, 4, 5]))
    (patch,) = t.patches_since(seq)
    np.testing.assert_array_equal(patch, [3, 4, 5])
    assert t.patches_since(t.mutation_version) == []


def test_append_uploads_delta_bytes_exactly():
    """The headline acceptance check: N new rows on a T-row resident table
    cost exactly N rows of upload, never T."""
    schema, t = make_table(5_000)
    eng = RelationalMemoryEngine(revision="xla")
    s, _ = eng.aggregate(t, "A1")
    full_bytes = t.row_count * t.row_bytes
    assert eng.stats.bytes_uploaded == full_bytes and eng.stats.uploads == 1
    assert eng.stats.bytes_uploaded_delta == 0

    n_new = 10
    t.append(fresh_rows(schema, n_new))
    assert not eng.rowstore.contains(t)  # pending delta
    s2, c2 = eng.aggregate(t, "A1")
    assert eng.stats.uploads == 2 and eng.stats.delta_uploads == 1
    assert eng.stats.bytes_uploaded_delta == n_new * t.row_bytes  # exact O(N)
    assert eng.stats.bytes_uploaded == full_bytes + n_new * t.row_bytes
    assert c2 == t.row_count
    expect = t.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(s2, expect, rtol=1e-6)


def test_delete_uploads_only_patched_timestamp_words():
    _, t = make_table(2_000)
    eng = RelationalMemoryEngine(revision="xla")
    _ = eng.aggregate(t, "A1")
    k = 17
    t.delete(np.arange(k))
    _ = eng.aggregate(t, "A1", snapshot_ts=t.now())
    assert eng.stats.delta_uploads == 1
    assert eng.stats.bytes_uploaded_delta == k * WORD  # one ts_end word/row


def test_update_uploads_patches_plus_replacement_tail():
    _, t = make_table(2_000)
    eng = RelationalMemoryEngine(revision="xla")
    _ = eng.aggregate(t, "A1")
    m = 5
    t.update(np.arange(m), {"A1": np.full(m, 999, np.int32)})
    s, c = eng.aggregate(t, "A1", snapshot_ts=t.now())
    # patched ts_end words of the m old versions + the m replacement rows
    assert eng.stats.bytes_uploaded_delta == m * WORD + m * t.row_bytes
    assert c == 2_000  # live count unchanged
    expect = t.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(s, expect, rtol=1e-6)


def test_sustained_appends_chunk_then_coalesce():
    """Tail chunks accumulate per append and coalesce past the cap — with
    zero additional host→device bytes for the coalesce."""
    schema, t = make_table(300)
    eng = RelationalMemoryEngine(revision="xla")
    _ = eng.device_words(t)
    for _ in range(3):
        t.append(fresh_rows(schema, 8))
        chunks = eng.device_chunks(t)
    assert len(chunks) == 4  # base + three tails
    assert sum(c.shape[0] for c in chunks) == t.row_count
    uploaded = eng.stats.bytes_uploaded
    assert eng.stats.bytes_uploaded_delta == 3 * 8 * t.row_bytes
    # device_words coalesces device-side: nothing more crosses the boundary
    words = eng.device_words(t)
    assert words.shape[0] == t.row_count
    assert eng.stats.bytes_uploaded == uploaded
    np.testing.assert_array_equal(np.asarray(words), t.words())


def test_patch_log_trim_falls_back_to_full_resync():
    _, t = make_table(64)
    eng = RelationalMemoryEngine(revision="xla")
    _ = eng.device_words(t)
    for i in range(MAX_PATCH_EVENTS + 8):  # overflow the log between syncs
        t.delete(np.array([i % 32]))
    t.update(np.arange(32, 40), {"A2": np.full(8, -1, np.int32)})
    words = np.asarray(eng.device_words(t))
    np.testing.assert_array_equal(words, t.words())  # correct via full re-sync
    assert eng.stats.uploads >= 2


def test_baseline_mode_reuploads_whole_table():
    """delta_uploads=False restores the pre-delta economics — the measurable
    baseline fig_htap_ingest compares against."""
    schema, t = make_table(1_000)
    eng = RelationalMemoryEngine(revision="xla", delta_uploads=False)
    _ = eng.aggregate(t, "A1")
    t.append(fresh_rows(schema, 1))
    _ = eng.aggregate(t, "A1")
    assert eng.stats.uploads == 2 and eng.stats.delta_uploads == 0
    assert eng.stats.bytes_uploaded == (1_000 + 1_001) * t.row_bytes


# ------------------------------------------------- delta-aware reorg cache
def test_hot_view_survives_append_via_tail_delta_scan():
    """Acceptance: the delta-served packed block equals a cold full
    materialization on a fresh engine, and only the tail was scanned."""
    schema, t = make_table(800)
    eng = RelationalMemoryEngine()
    _ = eng.register(t, ("A1", "A5")).packed()  # warm
    scanned_before = eng.stats.rows_projected
    t.append(fresh_rows(schema, 25))
    got = eng.register(t, ("A1", "A5")).packed()
    assert eng.stats.delta_hits == 1
    assert eng.stats.rows_projected == scanned_before + 25  # tail only
    cold = RelationalMemoryEngine().register(t, ("A1", "A5")).packed()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(cold))
    # and the merged block is a full hot hit next time
    hot_before = eng.stats.hot_hits
    _ = eng.register(t, ("A1", "A5")).packed()
    assert eng.stats.hot_hits == hot_before + 1


def test_hot_view_unperturbed_by_delete_and_update_patches():
    """Deletes rewrite only hidden timestamp words, which packed projections
    never contain — the cached block stays a *full* hot hit.  An update's
    append half extends it by a delta scan."""
    _, t = make_table(400)
    eng = RelationalMemoryEngine()
    _ = eng.register(t, ("A2", "A3")).packed()
    t.delete(np.arange(10))
    hot_before = eng.stats.hot_hits
    got = eng.register(t, ("A2", "A3")).packed()
    assert eng.stats.hot_hits == hot_before + 1  # delete did not stale it
    cold = RelationalMemoryEngine().register(t, ("A2", "A3")).packed()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(cold))

    t.update(np.arange(10, 15), {"A2": np.full(5, 123, np.int32)})
    got = eng.register(t, ("A2", "A3")).packed()
    assert eng.stats.delta_hits == 1  # replacements arrived via tail scan
    cold = RelationalMemoryEngine().register(t, ("A2", "A3")).packed()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(cold))


@pytest.mark.parametrize("write", ["append", "update", "delete"])
def test_delta_served_batch_equals_cold_rescan_every_op_kind(write):
    """Acceptance: after each write kind, a warm engine's mixed batch —
    projection, filter, aggregate, group-by — matches a cold engine's."""
    schema, t = make_table(300)
    warm = RelationalMemoryEngine()
    _ = warm.register(t, ("A1", "A2")).packed()  # warm one view
    _ = warm.aggregate(t, "A1")

    if write == "append":
        t.append(fresh_rows(schema, 11))
    elif write == "update":
        t.update(np.arange(7), {"A1": np.full(7, 555, np.int32)})
    else:
        t.delete(np.arange(5))

    ts = t.now()

    def run(eng):
        return eng.execute_many([
            ProjectOp(eng.register(t, ("A1", "A2"))),
            FilterOp(eng.register(t, ("A1", "A3")), "A2", "gt", 0,
                     snapshot_ts=ts),
            AggregateOp(t, "A1", snapshot_ts=ts),
            GroupByOp(t, "A2", "A1", 8, snapshot_ts=ts),
        ])

    got = run(warm)
    ref = run(RelationalMemoryEngine())
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1][0]), np.asarray(ref[1][0]))
    np.testing.assert_array_equal(np.asarray(got[1][1]), np.asarray(ref[1][1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(ref[2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got[3][0]), np.asarray(ref[3][0]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got[3][1]), np.asarray(ref[3][1]),
                               rtol=1e-6)


@pytest.mark.parametrize("revision", ["mlp", "xla"])
def test_chunked_fused_pass_matches_single_chunk(revision):
    """A multi-chunk table's shared scan (one kernel pass per chunk,
    partials combined) equals the same batch on a freshly-uploaded single
    chunk — for blocked and accumulated outputs alike."""
    schema, t = make_table(500)
    eng = RelationalMemoryEngine(revision=revision)
    _ = eng.device_words(t)
    t.append(fresh_rows(schema, 40, fill=3))
    _ = eng.device_chunks(t)  # sync between appends: each becomes a tail
    t.append(fresh_rows(schema, 24, fill=-2))
    ops = lambda e: [  # noqa: E731
        ProjectOp(e.register(t, ("A1", "A4"))),
        FilterOp(e.register(t, ("A2", "A3")), "A1", "gt", 0),
        AggregateOp(t, "A2", "A4", "lt", 5),
        GroupByOp(t, "A3", "A1", 8),
    ]
    chunks = eng.device_chunks(t)
    assert len(chunks) == 3  # base + two tails: genuinely chunk-iterating
    got = eng.execute_many(ops(eng))
    assert eng.stats.shared_scans == 1
    solo = RelationalMemoryEngine(revision=revision)
    ref = solo.execute_many(ops(solo))
    assert len(solo.rowstore.chunks(t)) == 1
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1][0]), np.asarray(ref[1][0]))
    np.testing.assert_array_equal(np.asarray(got[1][1]), np.asarray(ref[1][1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(ref[2]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got[3][0]), np.asarray(ref[3][0]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got[3][1]), np.asarray(ref[3][1]),
                               rtol=1e-5)


# ------------------------------------------------------- snapshot isolation
def test_reader_snapshot_is_byte_identical_across_writes():
    """Acceptance: a reader pinned at snapshot ``ts`` sees byte-identical
    results before and after concurrent append, update, and delete."""
    schema, t = make_table(400)
    eng = RelationalMemoryEngine()
    ts = t.now()
    before_col = np.asarray(eng.register(t, ("A1", "A2"), snapshot_ts=ts)
                            .column("A1"))
    before_agg = eng.aggregate(t, "A1", snapshot_ts=ts)
    before_filter = eng.execute_many([
        FilterOp(eng.register(t, ("A1", "A3")), "A2", "gt", 0, snapshot_ts=ts)
    ])[0]

    t.append(fresh_rows(schema, 20))
    t.update(np.arange(6), {"A1": np.full(6, -777, np.int32)})
    t.delete(np.arange(10, 16))

    after_col = np.asarray(eng.register(t, ("A1", "A2"), snapshot_ts=ts)
                           .column("A1"))
    np.testing.assert_array_equal(after_col, before_col)
    assert eng.aggregate(t, "A1", snapshot_ts=ts) == before_agg
    after_filter = eng.execute_many([
        FilterOp(eng.register(t, ("A1", "A3")), "A2", "gt", 0, snapshot_ts=ts)
    ])[0]
    # the packed block grew (new physical rows), but every row visible at ts
    # carries identical bytes and the new rows are masked out
    n_before = before_filter[0].shape[0]
    np.testing.assert_array_equal(np.asarray(after_filter[0])[:n_before],
                                  np.asarray(before_filter[0]))
    assert not np.asarray(after_filter[1])[n_before:].any()
    np.testing.assert_array_equal(np.asarray(after_filter[1])[:n_before],
                                  np.asarray(before_filter[1]))


def test_compile_plan_snapshot_routes_and_guards():
    _, t = make_table(200)
    eng = RelationalMemoryEngine()
    ts = t.now()
    t.update(np.arange(4), {"A1": np.full(4, 10_000, np.int32)})

    pinned = compile_plan(eng, plan(t).sum("A1"), snapshot_ts=ts)
    assert pinned.route == "fused-aggregate"
    live = compile_plan(eng, plan(t).sum("A1"), snapshot_ts=t.now())
    expect_old = t.read_column("A1", ts=ts).astype(np.float64).sum()
    expect_new = t.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(pinned.run(), expect_old, rtol=1e-6)
    np.testing.assert_allclose(live.run(), expect_new, rtol=1e-6)

    proj = compile_plan(eng, plan(t).project("A1", "A2"), snapshot_ts=t.now())
    assert proj.route == "snapshot-project"
    packed, mask = proj.run()
    assert int(np.asarray(mask).sum()) == 200  # live rows only
    with pytest.raises(PlanError, match="rme path"):
        compile_plan(eng, plan(t).sum("A1"), path="row", snapshot_ts=ts)


# ----------------------------------------------------- update() raw-word fix
def test_update_copies_untouched_columns_without_decode():
    """Untouched columns must be copied as raw words — never round-tripped
    through decode/encode."""
    import repro.core.table as table_mod
    from repro.core import Column, TableSchema

    schema = TableSchema.of(
        Column("key", "int64"),
        Column("tag", "char", 8),
        Column("val", "int32"),
        Column("score", "float32"),
    )
    t = RelationalTable.from_columns(schema, {
        "key": np.arange(10, dtype=np.int64),
        "tag": np.array([b"r\x00w%d" % i for i in range(10)]),
        "val": np.arange(10, dtype=np.int32),
        "score": np.linspace(-1, 1, 10).astype(np.float32),
    })
    raw_before = t.words()[np.arange(3), : schema.row_words].copy()

    calls = {"n": 0}
    real = table_mod._decode_column

    def counting(col, words):
        calls["n"] += 1
        return real(col, words)

    table_mod._decode_column = counting
    try:
        new_rows = t.update(np.arange(3), {"val": np.full(3, 99, np.int32)})
    finally:
        table_mod._decode_column = real
    assert calls["n"] == 0  # no decode round-trip for any column

    raw_after = t.words()[new_rows, : schema.row_words]
    val_off = schema.word_offset("val")
    untouched = [w for w in range(schema.row_words)
                 if not val_off <= w < val_off + 1]
    np.testing.assert_array_equal(raw_after[:, untouched],
                                  raw_before[:, untouched])
    np.testing.assert_array_equal(t.read_column_at("val", new_rows),
                                  np.full(3, 99, np.int32))
    with pytest.raises(KeyError):
        t.update(np.arange(2), {"nope": np.zeros(2, np.int32)})


# --------------------------------------------------- QueryServer write path
def test_server_writes_interleaved_with_reads_one_tick():
    schema, t = make_table(300)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng, snapshot_reads=True)
    _ = eng.aggregate(t, "A1")  # resident before the tick: writes are deltas
    live_sum = t.read_column("A1").astype(np.float64).sum()

    ins = server.submit_insert(t, fresh_rows(schema, 10, fill=100), client="w")
    agg = server.submit(plan(t).sum("A1"), client="r")
    dele = server.submit_delete(t, np.arange(5), client="w")
    cnt = server.submit(plan(t).count("A1"), client="r")
    assert server.run_tick() == 4

    rows = ins.result(timeout=5)
    assert len(rows) == 10 and ins.route == "write-insert"
    assert dele.result(timeout=5) is None and dele.route == "write-delete"
    # reads see the tick's post-write snapshot: +10 inserts, -5 deletes
    deleted = t.read_column_at("A1", np.arange(5)).astype(np.float64).sum()
    np.testing.assert_allclose(agg.result(timeout=5),
                               live_sum + 10 * 100 - deleted, rtol=1e-6)
    assert cnt.result(timeout=5) == 300 + 10 - 5
    assert server.stats.writes_applied == 2
    assert server.stats.rows_written == 15
    snap = server.snapshot()
    assert snap["writes_applied"] == 2
    assert snap["engine_delta_uploads"] >= 1


def test_server_update_ticket_mvcc_consistent_reads():
    schema, t = make_table(200)
    server = QueryServer(RelationalMemoryEngine(), snapshot_reads=True)
    upd = server.submit_update(t, np.arange(8),
                               {"A1": np.full(8, 1_000, np.int32)})
    cnt = server.submit(plan(t).count("A1"))
    total = server.submit(plan(t).sum("A1"))
    server.run_tick()
    assert len(upd.result(timeout=5)) == 8 and upd.route == "write-update"
    # MVCC: live count unchanged, sum reflects the replacements exactly once
    assert cnt.result(timeout=5) == 200
    np.testing.assert_allclose(
        total.result(timeout=5),
        t.read_column("A1").astype(np.float64).sum(), rtol=1e-6,
    )


def test_default_server_auto_pins_reads_once_writes_appear():
    """The review repro: a *default* server serving deletes/updates must not
    double-count row versions — the first write ticket flips reads to
    snapshot-pinned automatically."""
    schema = benchmark_schema(ROW_BYTES, 4)
    t = RelationalTable.from_columns(
        schema, {c.name: np.ones(100, np.int32) for c in schema.columns})
    server = QueryServer(RelationalMemoryEngine())  # defaults throughout
    server.submit_delete(t, np.arange(50))
    tk = server.submit(plan(t).sum("A1"))
    server.run_tick()
    assert tk.result(timeout=5) == 50.0  # not 100: deleted rows are invisible
    server.submit_update(t, np.arange(50, 60), {"A1": np.full(10, 4, np.int32)})
    tk2 = server.submit(plan(t).sum("A1"))
    server.run_tick()
    assert tk2.result(timeout=5) == 40 * 1 + 10 * 4  # each row counted once
    # deletes of already-dead / duplicate ids don't inflate rows_written
    before = server.stats.rows_written
    server.submit_delete(t, np.array([0, 0, 1, 2]))  # all already dead
    server.run_tick()
    assert server.stats.rows_written == before
    # ...and auto-pinning is per table: a never-written table's projections
    # keep the plain packed-array contract despite t's write traffic
    _, other = make_table(40, seed=3)
    tk3 = server.submit(plan(other).project("A1", "A2"))
    server.run_tick()
    packed = tk3.result(timeout=5)
    assert not isinstance(packed, tuple) and packed.shape == (40, 2)


def test_server_write_failure_resolves_only_its_ticket():
    schema, t = make_table(50)
    server = QueryServer(RelationalMemoryEngine())
    bad = server.submit_insert(t, {"A1": np.zeros(2, np.int32)})  # missing cols
    good = server.submit(plan(t).sum("A1"))
    server.run_tick()
    with pytest.raises(ValueError, match="missing columns"):
        bad.result(timeout=5)
    assert isinstance(good.result(timeout=5), float)
    assert server.stats.failed == 1 and server.stats.served == 1


def test_server_sustained_ingest_keeps_uploads_o_delta():
    """A write+read workload across many ticks ships O(delta) bytes — the
    benchmark's claim, held as an invariant at test scale."""
    schema, t = make_table(1_000)
    eng = RelationalMemoryEngine(revision="xla")
    server = QueryServer(eng, snapshot_reads=True)
    _ = eng.aggregate(t, "A1")  # resident
    base_bytes = eng.stats.bytes_uploaded
    appended = 0
    for i in range(6):
        server.submit_insert(t, fresh_rows(schema, 20, fill=i))
        server.submit(plan(t).sum("A1"))
        server.submit(plan(t).filter("A2", "gt", 0).avg("A3"))
        server.run_tick()
        appended += 20
    assert eng.stats.bytes_uploaded - base_bytes \
        == eng.stats.bytes_uploaded_delta
    assert eng.stats.bytes_uploaded_delta == appended * t.row_bytes
    # vs. the old behavior: six full re-uploads of a ~1000-row table
    assert eng.stats.bytes_uploaded_delta < 6 * 1_000 * t.row_bytes / 5


def test_snapshot_reads_server_still_serves_joins_and_host_paths():
    """snapshot_reads must only stamp plans that can carry a snapshot —
    joins and host-path baselines compile unpinned instead of erroring."""
    rng = np.random.default_rng(9)
    schema, t = make_table(120)
    r_cols = {c.name: rng.integers(-50, 50, 32).astype(np.int32)
              for c in schema.columns}
    r_cols["A2"] = np.arange(32, dtype=np.int32)
    rt = RelationalTable.from_columns(schema, r_cols)
    server = QueryServer(RelationalMemoryEngine(), snapshot_reads=True)
    jn = server.submit(plan(t).join(rt, key="A2", left_proj="A1",
                                    right_proj="A3"))
    rw = server.submit(plan(t).sum("A1"), path="row")
    server.run_tick()
    assert jn.result(timeout=5).matched.shape[0] == t.row_count
    np.testing.assert_allclose(
        rw.result(timeout=5), t.read_column("A1").astype(np.float64).sum(),
        rtol=1e-6,
    )
    assert server.stats.failed == 0


def test_cold_group_accounting_skips_delta_served_projections():
    """A delta-servable view never joins the shared pass, so the serving
    stats must not price it as a cold scan (bytes_saved honesty)."""
    schema, t = make_table(300)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    _ = eng.register(t, ("A1", "A2")).packed()  # warm
    t.append(fresh_rows(schema, 10))  # now delta-servable, not cold
    tk = server.submit(plan(t).project("A1", "A2"))
    server.run_tick()
    _ = tk.result(timeout=5)
    assert eng.stats.delta_hits == 1
    assert server.stats.table_groups == 0  # no cold group was opened
    assert server.stats.bytes_saved == 0


def test_ephemeral_column_reads_see_patched_timestamps():
    """view.column() masks against the *delta-synced* device timestamps —
    the patch upload, not a full re-ship, is what keeps it correct."""
    _, t = make_table(120)
    eng = RelationalMemoryEngine()
    view = eng.register(t, ("A1",))
    _ = view.packed()
    uploads = eng.stats.uploads
    t.delete(np.arange(30))
    live = np.asarray(eng.register(t, ("A1",)).column("A1"))
    assert live.shape[0] == 90
    np.testing.assert_array_equal(live, t.read_column("A1"))
    assert eng.stats.uploads == uploads + 1  # one delta sync
    assert eng.stats.bytes_uploaded_delta == 30 * WORD
