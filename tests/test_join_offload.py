"""Device-resident join offload: route equality, MVCC snapshots, coalescing.

The contract under test: the ``device-hash-join`` route (cached build-side
hash partitions + Pallas/XLA probe over the device row store) produces
bit-identical :class:`~repro.core.requests.JoinResult` outputs to the host
sort-probe route and the pure-jnp oracle, across every engine revision; a
snapshot-pinned join is byte-identical to joining frozen copies of both
tables; a mixed-kind server tick containing a join still performs exactly
one shared probe-side scan; and a Pallas lowering failure falls back to the
XLA probe without changing results.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    RelationalMemoryEngine,
    RelationalTable,
    benchmark_schema,
    compile_plan,
    decompose,
    plan,
)
from repro.core import operators as ops
from repro.core import planner
from repro.kernels import ref
from repro.kernels import rme_join as KJ
from repro.serve import QueryServer

REVISIONS = ("bsl", "pck", "mlp", "xla")
N_S, N_R = 500, 96


def _join_plan(t, rt):
    return plan(t).join(rt, key="A2", left_proj="A1", right_proj="A3")


@pytest.fixture
def table():
    rng = np.random.default_rng(3)
    schema = benchmark_schema(64, 4)
    cols = {c.name: rng.integers(-100, 100, N_S).astype(np.int32)
            for c in schema.columns}
    cols["A2"] = rng.integers(-20, 2 * N_R, N_S).astype(np.int32)
    return RelationalTable.from_columns(schema, cols)


@pytest.fixture
def build_table(table):
    rng = np.random.default_rng(7)
    cols = {c.name: rng.integers(-50, 50, N_R).astype(np.int32)
            for c in table.schema.columns}
    cols["A2"] = np.arange(N_R, dtype=np.int32)  # primary key
    return RelationalTable.from_columns(table.schema, cols)


def _assert_join_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.matched), np.asarray(b.matched))
    np.testing.assert_array_equal(np.asarray(a.r_proj), np.asarray(b.r_proj))
    np.testing.assert_array_equal(np.asarray(a.s_proj), np.asarray(b.s_proj))


# ------------------------------------------------------- route equality
@pytest.mark.parametrize("revision", REVISIONS)
def test_device_equals_host_equals_ref(table, build_table, revision):
    """device-hash-join == host sort-probe == kernels/ref.py, bit-exact."""
    eng = RelationalMemoryEngine(revision=revision)
    ops.clear_join_build_cache()
    q = _join_plan(table, build_table)
    pq = compile_plan(eng, q)
    assert pq.route == "device-hash-join"
    device = pq.run()
    host = compile_plan(eng, q, join_route="shared-scan-join").run()
    oracle_s, oracle_r, oracle_m = ref.hash_join_ref(
        jnp.asarray(table.read_column("A2")),
        jnp.asarray(table.read_column("A1")),
        jnp.asarray(build_table.read_column("A2")),
        jnp.asarray(build_table.read_column("A3")),
    )
    _assert_join_equal(device, host)
    np.testing.assert_array_equal(np.asarray(device.matched), np.asarray(oracle_m))
    np.testing.assert_array_equal(np.asarray(device.r_proj), np.asarray(oracle_r))
    np.testing.assert_array_equal(np.asarray(device.s_proj), np.asarray(oracle_s))
    assert np.asarray(device.matched).any()  # the fixture joins non-trivially


def test_stride_aligned_keys_spread_and_stay_exact(table):
    """Stride-aligned keys — the pattern that collapses a modulo hash into
    one bucket and blows the dense (P, C) arrays up to P x n words — must
    spread under the Fibonacci mix (bounded capacity) and join exactly."""
    rng = np.random.default_rng(1)
    n_r = 512
    cols = {c.name: rng.integers(-9, 9, n_r).astype(np.int32)
            for c in table.schema.columns}
    # every key ≡ 1 (mod any power-of-two bucket count ≤ 1024): one bucket
    # under `key mod P`, uniform under the multiplicative hash
    cols["A2"] = (np.arange(n_r, dtype=np.int32) * 1024) + 1
    rt = RelationalTable.from_columns(table.schema, cols)
    parts = KJ.build_partitions(cols["A2"], cols["A3"])
    assert parts.capacity <= 4 * KJ.TARGET_BUCKET_LOAD  # no blowup
    assert parts.nbytes <= 8 * KJ.estimated_partition_bytes(n_r)
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = _join_plan(table, rt)
    device = compile_plan(eng, q).run()
    host = compile_plan(eng, q, join_route="shared-scan-join").run()
    _assert_join_equal(device, host)


def test_partition_invariants():
    """Kernel-level honesty: capacity is the max occupancy, no key is lost,
    and empty-slot fills can never hash to the bucket that holds them."""
    rng = np.random.default_rng(5)
    key = rng.choice(np.arange(-500, 500, dtype=np.int32), 200, replace=False)
    parts = KJ.build_partitions(key, np.ones(200, np.int32))
    p, c = parts.num_buckets, parts.capacity
    g = KJ.bucket_of_np(key, p)
    assert c == np.bincount(g, minlength=p).max()
    keys = np.asarray(parts.keys)
    fills = KJ.bucket_fills(p)
    for b in range(p):
        in_bucket = np.sort(key[g == b])
        slots = keys[b]
        real = slots[KJ.bucket_of_np(slots, p) == b]
        assert np.array_equal(np.sort(real), in_bucket)  # nothing lost
        pad = slots[KJ.bucket_of_np(slots, p) != b]
        assert (pad == fills[b]).all()  # fill never hashes to its own bucket
    # the fill-safety theorem itself, for every bucket count the builder uses
    for pb in (2, 8, 64, 1024):
        f = KJ.bucket_fills(pb)
        assert (KJ.bucket_of_np(f, pb) != np.arange(pb)).all()


# ------------------------------------------------------- MVCC snapshots
def test_snapshot_join_byte_identical_to_frozen_copy(table, build_table):
    """A snapshot-pinned join under concurrent writes on BOTH sides equals
    the plain join of copies frozen at the snapshot."""
    frozen_s = RelationalTable.from_columns(
        table.schema,
        {c.name: table.read_column(c.name) for c in table.schema.columns},
    )
    frozen_r = RelationalTable.from_columns(
        build_table.schema,
        {c.name: build_table.read_column(c.name)
         for c in build_table.schema.columns},
    )
    ts0 = max(table.now(), build_table.now())
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = _join_plan(table, build_table)
    pinned = compile_plan(eng, q, snapshot_ts=ts0)
    assert pinned.route == "device-hash-join"

    # concurrent writes: delete + update probe rows, delete build rows,
    # append rows on both sides
    table.delete(np.arange(25))
    table.update(np.arange(30, 40),
                 {"A1": np.full(10, 7777, np.int32)})
    build_table.delete(np.arange(10, 30))
    table.append({c.name: np.full(8, 3, np.int32)
                  for c in table.schema.columns})
    build_table.append({c.name: np.full(4, 2, np.int32)
                        for c in build_table.schema.columns})

    got = pinned.run()
    want = compile_plan(RelationalMemoryEngine(),
                        _join_plan(frozen_s, frozen_r)).run()
    n0 = frozen_s.row_count
    got_m = np.asarray(got.matched)
    np.testing.assert_array_equal(got_m[:n0], np.asarray(want.matched))
    np.testing.assert_array_equal(np.asarray(got.r_proj)[:n0],
                                  np.asarray(want.r_proj))
    np.testing.assert_array_equal(np.asarray(got.s_proj)[:n0],
                                  np.asarray(want.s_proj))
    # physical rows born after the snapshot are invisible: zeros, unmatched
    assert not got_m[n0:].any()
    assert np.asarray(got.s_proj)[n0:].sum() == 0
    assert np.asarray(got.r_proj)[n0:].sum() == 0


def test_snapshot_join_through_query_server(table, build_table):
    """Acceptance: a join submitted with a snapshot through the QueryServer
    no longer raises PlanError — it serves from the post-write tick snapshot."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    plain = compile_plan(eng, _join_plan(table, build_table)).run()

    server = QueryServer(eng)  # auto snapshot mode: pins on first write
    server.submit_delete(table, np.arange(15))
    tk = server.submit(_join_plan(table, build_table))
    server.run_tick()
    res = tk.result(timeout=30)
    assert tk.route == "device-hash-join"
    m = np.asarray(res.matched)
    assert not m[:15].any()  # tick-deleted probe rows are invisible
    np.testing.assert_array_equal(m[15:], np.asarray(plain.matched)[15:])

    # forced-snapshot mode serves a build-side write the same way
    server2 = QueryServer(eng, snapshot_reads=True)
    server2.submit_delete(build_table, np.arange(5))
    tk2 = server2.submit(_join_plan(table, build_table))
    server2.run_tick()
    res2 = tk2.result(timeout=30)
    # one slot per *physical* probe row: read keys from the raw row store
    keys = table.words()[:, table.schema.word_offset("A2")]
    dead = np.isin(keys, np.arange(5))
    assert not (np.asarray(res2.matched) & dead).any()


# ------------------------------------------------- tick coalescing
def test_mixed_tick_with_join_is_one_shared_scan(table, build_table):
    """A tick mixing a join with co-tick filters/aggregates/group-bys on the
    probe table performs exactly ONE shared probe-side scan (the join's
    probe-side projection rides the same fused pass)."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    server = QueryServer(eng)
    tks = [
        server.submit(_join_plan(table, build_table)),
        server.submit(plan(table).filter("A3", "gt", 0).sum("A1")),
        server.submit(plan(table).groupby("A4", "A1", "avg", 8)),
        server.submit(plan(table).filter("A5", "lt", 0).project("A2")),
    ]
    server.run_tick()
    results = [tk.result(timeout=30) for tk in tks]
    assert eng.stats.shared_scans == 1  # one pass served every kind + join
    ref_join = compile_plan(RelationalMemoryEngine(),
                            _join_plan(table, build_table)).run()
    _assert_join_equal(results[0], ref_join)
    a1, a3 = table.read_column("A1"), table.read_column("A3")
    assert results[1] == pytest.approx(float(a1[a3 > 0].sum()))


def test_join_dedupes_with_same_view_projection(table, build_table):
    """A co-tick projection of exactly the join's probe view shares one
    output slot in the fused pass — and the packed block still crosses to
    the CPU only for the projection consumer."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    server = QueryServer(eng)
    tk_join = server.submit(_join_plan(table, build_table))
    tk_proj = server.submit(plan(table).project("A1", "A2"))
    server.run_tick()
    res_join, res_proj = tk_join.result(timeout=30), tk_proj.result(timeout=30)
    assert eng.stats.shared_scans == 0  # dedupe left one request: solo kernel
    expect = eng.register(table, ("A1", "A2")).packed()
    np.testing.assert_array_equal(np.asarray(res_proj), np.asarray(expect))
    assert np.asarray(res_join.matched).any()


def test_solo_device_join_moves_fewer_bytes_than_host(table, build_table):
    """The fig12 criterion at test scale: on one engine, the device route's
    row-store + hierarchy bytes are strictly below the host sort-probe's."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = _join_plan(table, build_table)

    eng.stats.reset()
    compile_plan(eng, q, join_route="device-hash-join").run()
    device = (eng.stats.bytes_from_dram + eng.stats.bytes_to_cpu
              + eng.stats.bytes_uploaded)

    eng.cache.reset()
    ops.clear_join_build_cache()
    eng.rowstore.clear()
    eng.stats.reset()
    compile_plan(eng, q, join_route="shared-scan-join").run()
    host = (eng.stats.bytes_from_dram + eng.stats.bytes_to_cpu
            + eng.stats.bytes_uploaded)
    assert device < host


def test_route_chooser_prefers_host_when_everything_is_warm(
    table, build_table
):
    """Cost model sanity: with the probe view hot in the reorg cache and the
    sorted index cached, the host sort-probe costs ~0 bytes and wins."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = _join_plan(table, build_table)
    compile_plan(eng, q, join_route="shared-scan-join").run()  # warm both
    assert compile_plan(eng, q).route == "shared-scan-join"


def test_partition_cache_invalidates_on_build_mutation(table, build_table):
    """A build-side write changes the version key: the next compile misses,
    rebuilds, and the dead version's buckets are dropped rather than
    accumulating.  A snapshot pinned *before* the write keeps resolving the
    pre-write payload out of the freshly built buckets (MVCC on the build
    side)."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = _join_plan(table, build_table)
    first = compile_plan(eng, q).run()
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 1}
    ts0 = max(table.now(), build_table.now())
    build_table.update(np.array([0]), {"A3": np.array([999], np.int32)})
    pinned = compile_plan(eng, q, snapshot_ts=ts0).run()
    assert ops.JOIN_BUILD_STATS["misses"] == 2
    keys = [k for k in ops._BUILD_INDEX_CACHE if k[0] == build_table.uid]
    assert len(keys) == 1  # the dead version's buckets were dropped
    # pinned before the update: byte-identical to the pre-write join
    _assert_join_equal(pinned, first)


def test_probe_streams_multiple_resident_chunks(table, build_table):
    """A probe table grown after residency keeps base + tail chunks; the
    solo probe streams each chunk and concatenates — equal to the
    single-buffer answer."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    eng.device_words(table)  # resident at the pre-append watermark
    n_new = 40
    table.append({c.name: np.arange(n_new, dtype=np.int32)
                  for c in table.schema.columns})
    assert len(eng.device_chunks(table)) == 2  # base + appended tail
    got = compile_plan(eng, _join_plan(table, build_table)).run()
    want = compile_plan(RelationalMemoryEngine(),
                        _join_plan(table, build_table)).run()
    _assert_join_equal(got, want)
    assert np.asarray(got.matched).shape[0] == table.row_count


# ------------------------------------------------- lowering fallback
def test_fallback_when_device_lowering_fails(table, build_table, monkeypatch):
    """A Pallas probe failure falls back to the XLA fused-gather probe with
    identical results — one query's lowering error never loses the join."""
    calls = {"n": 0}

    def boom(*args, **kwargs):
        calls["n"] += 1
        raise RuntimeError("synthetic lowering failure")

    import repro.kernels.ops as kernel_ops

    monkeypatch.setattr(kernel_ops, "hash_join", boom)
    eng = RelationalMemoryEngine(revision="mlp")
    ops.clear_join_build_cache()
    got = compile_plan(eng, _join_plan(table, build_table)).run()
    assert calls["n"] == 1  # the Pallas probe was attempted and failed
    want = compile_plan(RelationalMemoryEngine(),
                        _join_plan(table, build_table)).run()
    _assert_join_equal(got, want)


def test_inexpressible_join_routes_to_host(table):
    """A char key cannot ride the device probe (integer-modulo hash): the
    chooser falls back to the host sort-probe, and asking for a snapshot —
    which only the device route can pin — fails loudly at compile time."""
    from repro.core.plan import PlanError

    char_schema = benchmark_schema(64, 8)  # char columns
    wide = RelationalTable.from_columns(
        char_schema,
        {c.name: np.full(8, b"x", dtype="S8") for c in char_schema.columns},
    )
    eng = RelationalMemoryEngine()
    q = plan(wide).join(wide, key="A2", left_proj="A1", right_proj="A3")
    shape = decompose(q)
    assert not planner._device_join_expressible(shape)
    assert planner._join_route(eng, shape, None) == "shared-scan-join"
    with pytest.raises(PlanError):
        compile_plan(eng, q, snapshot_ts=0)
