"""Property tests for the Requestor's Eq. (1)-(6) descriptor math.

The software fetch model must reconstruct the packed projection byte-exactly
from raw memory for ANY word-aligned geometry, and every descriptor must
satisfy the paper's alignment/over-fetch invariants.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TableGeometry, benchmark_schema, descriptors, fetch_model
from repro.core.descriptor import bytes_moved, descriptor_arrays
from repro.core.schema import WORD
from repro.core.table import RelationalTable


@st.composite
def geometries(draw):
    """Random word-aligned geometry with non-overlapping enabled columns."""
    row_words = draw(st.integers(2, 64))
    n_cols = draw(st.integers(1, min(11, row_words)))
    # pick distinct word offsets and widths that fit without overlap
    starts = sorted(draw(
        st.lists(st.integers(0, row_words - 1), min_size=n_cols,
                 max_size=n_cols, unique=True)
    ))
    widths = []
    for i, s in enumerate(starts):
        limit = (starts[i + 1] if i + 1 < n_cols else row_words) - s
        widths.append(draw(st.integers(1, min(limit, 16))))
    rel = [starts[0] * WORD]
    for i in range(1, n_cols):
        rel.append((starts[i] - starts[i - 1]) * WORD)
    rows = draw(st.integers(1, 200))
    return TableGeometry(
        row_bytes=row_words * WORD,
        row_count=rows,
        col_widths=tuple(w * WORD for w in widths),
        col_rel_offsets=tuple(rel),
    )


@given(geometries(), st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=150, deadline=None)
def test_fetch_model_reconstructs_exactly(geom, bus_width):
    rng = np.random.default_rng(42)
    memory = rng.integers(0, 256, geom.row_bytes * geom.row_count, dtype=np.uint8)
    out, beats = fetch_model(memory, geom, bus_width)
    # oracle: slice each enabled column out of each row
    expect = []
    for i in range(geom.row_count):
        row = memory[i * geom.row_bytes : (i + 1) * geom.row_bytes]
        for off, w in zip(geom.abs_offsets, geom.col_widths):
            expect.append(row[off : off + w])
    np.testing.assert_array_equal(out, np.concatenate(expect))
    assert beats >= -(-geom.out_bytes_per_row * geom.row_count // bus_width)


@given(geometries(), st.sampled_from([8, 16, 32]))
@settings(max_examples=150, deadline=None)
def test_descriptor_invariants(geom, bus_width):
    """Paper Eq. (2)-(6): alignment, bounded burst, bounded over-fetch."""
    for d in descriptors(geom, bus_width):
        width = geom.col_widths[d.j]
        assert d.r_addr % bus_width == 0  # bus-aligned start (Eq. 2)
        assert d.e_start < bus_width  # leading discard < one beat (Eq. 5)
        # burst covers the column with < one beat of slack on either side
        assert d.r_burst * bus_width >= width
        assert d.r_burst * bus_width < width + 2 * bus_width
        # reconstruction window stays inside the burst
        assert d.e_start + width <= d.r_burst * bus_width
        # Eq. (1): burst covers P_{i,j}
        p = geom.row_bytes * d.i + geom.abs_offsets[d.j]
        assert d.r_addr <= p < d.r_addr + d.r_burst * bus_width


@given(geometries())
@settings(max_examples=80, deadline=None)
def test_bytes_moved_ordering(geom):
    """columnar <= rme <= row_wise + slack: the paper's Figure-1 economics."""
    m = bytes_moved(geom)
    assert m["columnar"] <= m["rme"]
    # Eq. (3): a burst over-fetches strictly less than one bus word at each
    # end, so the slack is < 2·B_w per (row, column) — e.g. an 8 B column at
    # offset ≡ 12 (mod 16) costs two 16 B beats = 24 B of slack
    assert m["rme"] < m["columnar"] + 2 * 16 * geom.row_count * geom.q + 16
    assert m["columnar"] == geom.row_count * geom.out_bytes_per_row


def test_vectorized_matches_scalar():
    schema = benchmark_schema(64, 4)
    geom = TableGeometry.from_schema(schema, ["A1", "A7", "A13"], 100)
    arrs = descriptor_arrays(geom)
    descs = descriptors(geom)
    for d in descs:
        assert arrs["r_addr"][d.i, d.j] == d.r_addr
        assert arrs["r_burst"][d.i, d.j] == d.r_burst
        assert arrs["w_addr"][d.i, d.j] == d.w_addr
        assert arrs["e_start"][d.i, d.j] == d.e_start
        assert arrs["e_end"][d.i, d.j] == d.e_end


def test_offset_insensitivity():
    """Fig. 6's second message: burst count is offset-independent except when
    the column straddles a bus line (the paper's spikes at offsets 13-15,
    29-31, 45-47 — at word granularity: an 8B column at offset ≡ 12 mod 16)."""
    n = 64
    beats = {}
    for off_words in range(0, 14):
        geom = TableGeometry(
            row_bytes=64, row_count=n, col_widths=(8,),
            col_rel_offsets=(off_words * WORD,),
        )
        rng = np.random.default_rng(0)
        mem = rng.integers(0, 256, geom.row_bytes * n, dtype=np.uint8)
        _, b = fetch_model(mem, geom, bus_width=16)
        beats[off_words * WORD] = b
    base = beats[0]
    for off, b in beats.items():
        if off % 16 == 12:  # 8B column starting 4B before a bus boundary
            assert b == 2 * base, (off, b, base)  # the paper's spike
        else:
            assert b == base, (off, b, base)
