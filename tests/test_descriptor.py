"""Property tests for the Requestor's Eq. (1)-(6) descriptor math.

The software fetch model must reconstruct the packed projection byte-exactly
from raw memory for ANY word-aligned geometry, and every descriptor must
satisfy the paper's alignment/over-fetch invariants.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TableGeometry, descriptors, fetch_model
from repro.core.descriptor import bytes_moved
from repro.core.schema import WORD


@st.composite
def geometries(draw):
    """Random word-aligned geometry with non-overlapping enabled columns."""
    row_words = draw(st.integers(2, 64))
    n_cols = draw(st.integers(1, min(11, row_words)))
    # pick distinct word offsets and widths that fit without overlap
    starts = sorted(draw(
        st.lists(st.integers(0, row_words - 1), min_size=n_cols,
                 max_size=n_cols, unique=True)
    ))
    widths = []
    for i, s in enumerate(starts):
        limit = (starts[i + 1] if i + 1 < n_cols else row_words) - s
        widths.append(draw(st.integers(1, min(limit, 16))))
    rel = [starts[0] * WORD]
    for i in range(1, n_cols):
        rel.append((starts[i] - starts[i - 1]) * WORD)
    rows = draw(st.integers(1, 200))
    return TableGeometry(
        row_bytes=row_words * WORD,
        row_count=rows,
        col_widths=tuple(w * WORD for w in widths),
        col_rel_offsets=tuple(rel),
    )


@given(geometries(), st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=150, deadline=None)
def test_fetch_model_reconstructs_exactly(geom, bus_width):
    rng = np.random.default_rng(42)
    memory = rng.integers(0, 256, geom.row_bytes * geom.row_count, dtype=np.uint8)
    out, beats = fetch_model(memory, geom, bus_width)
    # oracle: slice each enabled column out of each row
    expect = []
    for i in range(geom.row_count):
        row = memory[i * geom.row_bytes : (i + 1) * geom.row_bytes]
        for off, w in zip(geom.abs_offsets, geom.col_widths):
            expect.append(row[off : off + w])
    np.testing.assert_array_equal(out, np.concatenate(expect))
    assert beats >= -(-geom.out_bytes_per_row * geom.row_count // bus_width)


@given(geometries(), st.sampled_from([8, 16, 32]))
@settings(max_examples=150, deadline=None)
def test_descriptor_invariants(geom, bus_width):
    """Paper Eq. (2)-(6): alignment, bounded burst, bounded over-fetch."""
    for d in descriptors(geom, bus_width):
        width = geom.col_widths[d.j]
        assert d.r_addr % bus_width == 0  # bus-aligned start (Eq. 2)
        assert d.e_start < bus_width  # leading discard < one beat (Eq. 5)
        # burst covers the column with < one beat of slack on either side
        assert d.r_burst * bus_width >= width
        assert d.r_burst * bus_width < width + 2 * bus_width
        # reconstruction window stays inside the burst
        assert d.e_start + width <= d.r_burst * bus_width
        # Eq. (1): burst covers P_{i,j}
        p = geom.row_bytes * d.i + geom.abs_offsets[d.j]
        assert d.r_addr <= p < d.r_addr + d.r_burst * bus_width


@given(geometries())
@settings(max_examples=80, deadline=None)
def test_bytes_moved_ordering(geom):
    """columnar <= rme <= row_wise + slack: the paper's Figure-1 economics."""
    m = bytes_moved(geom)
    assert m["columnar"] <= m["rme"]
    # Eq. (3): a burst over-fetches strictly less than one bus word at each
    # end, so the slack is < 2·B_w per (row, column) — e.g. an 8 B column at
    # offset ≡ 12 (mod 16) costs two 16 B beats = 24 B of slack
    assert m["rme"] < m["columnar"] + 2 * 16 * geom.row_count * geom.q + 16
    assert m["columnar"] == geom.row_count * geom.out_bytes_per_row


# test_vectorized_matches_scalar / test_offset_insensitivity live in
# test_descriptor_basic.py so they run without hypothesis.
