"""Flash-attention kernel sweeps vs the pure-jnp oracle (interpret mode)."""


import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L


def oracle(q, k, v, causal=True, window=None):
    spec = L.AttnSpec(
        d_model=q.shape[-1] * q.shape[2], n_heads=q.shape[2],
        n_kv_heads=k.shape[2], head_dim=q.shape[-1],
        window=window, causal=causal,
    )
    return L.blockwise_attention(q, k, v, spec, chunk=max(q.shape[1] // 2, 1))


CASES = [
    # (B, S, H, KH, D, causal, window, block_q, block_k)
    (2, 128, 4, 4, 32, True, None, 64, 64),
    (2, 128, 8, 2, 32, True, None, 64, 32),  # GQA group 4
    (1, 256, 4, 1, 64, True, None, 128, 128),  # MQA
    (2, 96, 4, 2, 32, True, None, 64, 64),  # padded tail (96 % 64 != 0)
    (2, 128, 4, 4, 32, True, 48, 64, 64),  # sliding window
    (2, 128, 4, 4, 32, False, None, 64, 64),  # bidirectional (encoder)
    (1, 64, 2, 2, 128, True, None, 32, 32),  # MXU-wide head dim
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_oracle(case, dtype):
    b, s, h, kh, d, causal, window, bq, bk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kh, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kh, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = oracle(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_block_shape_sweep():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (1, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 32)), jnp.float32)
    ref = oracle(q, k, v)
    for bq in (32, 64, 256):
        for bk in (32, 128, 256):
            out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                                  interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"bq={bq} bk={bk}")


def test_flash_numerical_stability_large_logits():
    """Online softmax must survive logits far beyond exp() range."""
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(0, 30, (1, 64, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 30, (1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    ref = oracle(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
