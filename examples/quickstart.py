"""Quickstart: Relational Memory in five minutes.

Builds the paper's benchmark relation, registers ephemeral column-group
views, and runs the full Q0–Q5 suite over the three access paths, printing
the data-movement economics that motivate the design (paper Fig. 1).

Run:  PYTHONPATH=src python examples/quickstart.py
      (REPRO_SMOKE=1 shrinks the tables so it finishes in seconds — what the
       CI docs-and-examples leg runs)
"""

import os

import numpy as np

from repro.core import (
    RelationalMemoryEngine,
    RelationalTable,
    TableGeometry,
    benchmark_schema,
    bytes_moved,
)
from repro.core import operators as ops


SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main() -> None:
    # 1. A row-major relation (the single source of truth; OLTP-friendly)
    rng = np.random.default_rng(0)
    schema = benchmark_schema(row_bytes=64, col_bytes=4)  # 16 × int32 columns
    n = 2_000 if SMOKE else 44_000  # the paper's default cardinality
    table = RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-1000, 1000, n).astype(np.int32)
         for c in schema.columns},
    )
    print(f"table: {n} rows × {schema.row_bytes}B (row-major, MVCC)")

    # 2. The engine + an ephemeral view (the configuration-port write);
    #    nothing is materialized until first access
    engine = RelationalMemoryEngine(revision="mlp")
    view = engine.register(table, ("A1", "A7", "A13"))
    print(f"registered {view!r}")

    packed = view.packed()  # cold: the RME assembles the packed projection
    print(f"cold access -> packed {packed.shape}, "
          f"engine stats: {engine.stats}")
    _ = view.packed()  # hot: served from the reorganization cache
    print(f"hot access  -> hits={engine.stats.hot_hits}")

    # 3. Data-movement economics (what the caches see)
    geom = TableGeometry.from_schema(schema, ["A1", "A7", "A13"], n)
    moved = bytes_moved(geom)
    print(f"bytes through the hierarchy: row-wise={moved['row_wise']:,} "
          f"rme={moved['rme']:,} columnar={moved['columnar']:,} "
          f"(rme saves {moved['row_wise'] / moved['rme']:.1f}× vs rows)")

    # 4. The whole benchmark: Q0-Q5, three interchangeable paths
    cs = ops.make_colstore(table, list(schema.names))
    print(f"Q0 sum      : {ops.q0_sum(engine, table, 'A1'):.0f} "
          f"(col path agrees: {ops.q0_sum(engine, table, 'A1', path='col', colstore=cs):.0f})")
    print(f"Q1 project  : {ops.q1_project(engine, table, ('A1','A2')).shape}")
    vals, mask = ops.q2_select_project(engine, table, "A1", "A3", 100)
    print(f"Q2 select   : {int(mask.sum())} rows pass")
    print(f"Q3 agg      : {ops.q3_select_aggregate(engine, table, 'A2', 'A4', 0):.0f}")
    print(f"Q4 group-by : {np.asarray(ops.q4_groupby_avg(engine, table)).shape} group means")
    n_r = 512 if SMOKE else 4096
    r = RelationalTable.from_columns(schema, {
        c.name: (np.arange(n_r, dtype=np.int32) if c.name == "A2"
                 else rng.integers(-9, 9, n_r).astype(np.int32))
        for c in schema.columns})
    j = ops.q5_hash_join(engine, table, r)
    print(f"Q5 join     : {int(j.matched.sum())} of {n} probe rows matched")

    # 5. OLTP writes flow through at delta cost: the appended row ships as a
    #    tail chunk and the hot view extends by a tail-only scan — no manual
    #    invalidation, no re-materialization
    table.append({name: np.array([1], np.int32) for name in schema.names})
    _ = engine.register(table, ("A1", "A7", "A13")).packed()
    print(f"after append -> delta_hits={engine.stats.delta_hits}, "
          f"delta upload={engine.stats.bytes_uploaded_delta}B "
          f"(the view grew incrementally; see examples/htap_writes.py)")


if __name__ == "__main__":
    main()
