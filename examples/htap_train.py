"""End-to-end HTAP training driver — the paper's architecture as an ML system.

Samples are ingested ROW-MAJOR into an MVCC record store (OLTP side); the
trainer consumes EPHEMERAL PROJECTIONS of exactly (tokens, labels) (OLAP
side).  Mid-run, fresh data is ingested concurrently — the pinned snapshot
keeps the batch stream reproducible — and the run survives a simulated
preemption through the checkpoint/restore path.

Run:  PYTHONPATH=src python examples/htap_train.py [--steps 150] [--d-model 128]
      (--d-model 512 --layers 8 --vocab 32768 gives the ~100M-param variant;
       the default is CPU-sized so the example finishes in minutes, and
       REPRO_SMOKE=1 shrinks it to a seconds-long CI probe)
"""

import argparse
import dataclasses
import os
import tempfile


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import RecordStore, TrainPipeline, synthetic_corpus
from repro.models import build_model
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20 if smoke else 150)
    ap.add_argument("--d-model", type=int, default=64 if smoke else 128)
    ap.add_argument("--layers", type=int, default=2 if smoke else 4)
    ap.add_argument("--vocab", type=int, default=512 if smoke else 4096)
    ap.add_argument("--seq", type=int, default=32 if smoke else 128)
    ap.add_argument("--batch", type=int, default=4 if smoke else 16)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ArchConfig(
        name="htap-demo", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(args.d_model // 32, 2),
        n_kv_heads=max(args.d_model // 64, 1), d_ff=args.d_model * 3,
        vocab=args.vocab, rope_theta=1e4, attn_chunk=64, loss_chunk=64,
        compute_dtype="float32",
    )
    model = build_model(cfg)
    n_params = sum(p.size for p in jax.tree.leaves(model.init(jax.random.PRNGKey(0))))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

    # ---- OLTP: ingest the corpus row-major
    store = RecordStore(seq_len=args.seq)
    tok, lab = synthetic_corpus(1024, args.seq, cfg.vocab, seed=1)
    store.ingest(tok, lab)
    print(f"ingested {store.n_rows} row-major records "
          f"({store.table.nbytes()/2**20:.1f} MiB)")

    # ---- OLAP: the trainer reads ephemeral (tokens, labels) projections
    pipe = TrainPipeline(store, batch_size=args.batch, seed=0)
    to_jnp = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=min(20, args.steps // 4),
                           decay_steps=args.steps)))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="htap_ckpt_")
    half = args.steps // 2
    tcfg = TrainerConfig(total_steps=half, ckpt_dir=ckpt_dir,
                         ckpt_every=max(half // 2, 10), log_every=10)
    trainer = Trainer(step_fn, init_train_state(model, jax.random.PRNGKey(0)),
                      (to_jnp(b) for b in pipe.batches()), tcfg)
    hist = trainer.run()
    print(f"[phase 1] step {trainer.step}: "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # ---- concurrent OLTP ingest (does NOT perturb the pinned snapshot)
    store.ingest(*synthetic_corpus(256, args.seq, cfg.vocab, seed=2))
    print(f"[ingest] store now holds {store.n_rows} rows; "
          f"engine views invalidated transparently")

    # ---- simulated preemption: fresh process state, restore, continue
    trainer2 = Trainer(
        step_fn, init_train_state(model, jax.random.PRNGKey(123)),
        (to_jnp(b) for b in pipe.batches(start_step=trainer.step)),
        dataclasses.replace(tcfg, total_steps=args.steps),
    )
    assert trainer2.try_restore(), "checkpoint restore failed"
    print(f"[restore] resumed at step {trainer2.step}")
    hist2 = trainer2.run()
    print(f"[phase 2] step {trainer2.step}: loss {hist2[-1]['loss']:.3f}")
    if not smoke:  # a handful of smoke steps is API coverage, not convergence
        assert hist2[-1]["loss"] < hist[0]["loss"], "training failed to improve"
    print("HTAP train driver complete: ingest → project → train → "
          "ingest → preempt → restore → train.")


if __name__ == "__main__":
    main()
