"""Batched serving example: continuous batching over a shared KV cache.

Eight requests share four batch slots; the session admits, decodes, retires
and refills slots with one jitted decode step — the serve-side shape the
decode_32k / long_500k dry-run cells lower at production scale.

Run:  PYTHONPATH=src python examples/serving.py [--arch qwen3-8b]
      (REPRO_SMOKE=1 shrinks requests/decode length to CI scale)
"""

import argparse
import os
import time

import numpy as np

import jax

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model
from repro.serve import ServeSession
from repro.serve.engine import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    choices=[a for a in ARCH_NAMES])
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    ap.add_argument("--slots", type=int, default=2 if smoke else 4)
    ap.add_argument("--requests", type=int, default=3 if smoke else 8)
    ap.add_argument("--max-new", type=int, default=4 if smoke else 12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if not cfg.embed_inputs and not cfg.is_encdec:
        raise SystemExit(f"{args.arch} takes precomputed embeddings; pick a "
                         "token-input arch for this example")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = ServeSession(model, params, batch_slots=args.slots, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8 + i).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        sess.submit(r)

    t0 = time.perf_counter()
    ticks = 0
    while sess.tick() or sess.queue:
        ticks += 1
        if ticks > 10_000:
            break
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"arch={cfg.name}: {len(reqs)} requests / {args.slots} slots, "
          f"{ticks} decode ticks, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.0f} tok/s)")
    for r in reqs:
        assert r.done
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
