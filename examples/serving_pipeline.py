"""Pipelined serving example: priority lanes, deadlines, streaming results.

A bulk analytics backlog and point reads share one QueryServer: the point
reads ride the express lane and resolve ahead of the backlog, a deliberately
impossible deadline fails typed instead of hanging, a large projection
streams back chunk by chunk, and the per-lane latency percentiles land in
``snapshot()``.  See docs/serving.md for the operations guide.

Run:  PYTHONPATH=src python examples/serving_pipeline.py
      (REPRO_SMOKE=1 shrinks the table to CI scale)
"""

import os

import numpy as np

from repro.core import RelationalTable, benchmark_schema, plan
from repro.serve import DeadlineExceeded, QueryServer, ServerOverloaded


def main() -> None:
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    n_rows = 5_000 if smoke else 100_000
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    table = RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-1000, 1000, n_rows).astype(np.int32)
         for c in schema.columns},
    )

    server = QueryServer(max_batch=4, max_queue=64)

    # a backlog of bulk analytics, then point reads arriving behind it
    bulk = [server.submit(plan(table).project("A1", "A2", "A3", "A4"),
                          client="analytics")
            for _ in range(8)]
    points = [server.submit(plan(table).filter("A4", "gt", k).sum("A2"),
                            client="point", deadline_s=30.0)
              for k in range(3)]
    doomed = server.submit(plan(table).sum("A1"), deadline_s=0.0)
    streamed = server.submit(plan(table).project("A1", "A2"), stream=True,
                             stream_chunk_rows=max(n_rows // 8, 32),
                             client="export")

    server.drain()

    for tk in points:
        assert tk.lane == "express"
        print(f"point read ({tk.client}): lane={tk.lane} "
              f"latency={tk.latency_s * 1e3:.2f}ms -> {tk.result(timeout=30):.1f}")
    try:
        doomed.result(timeout=30)
        raise AssertionError("expired ticket should not resolve")
    except DeadlineExceeded as e:
        print(f"deadline miss -> typed failure: {type(e).__name__}: {e}")
    except ServerOverloaded:  # pragma: no cover - not expected here
        raise

    chunks = [np.asarray(c) for c in streamed.chunks(timeout=30)]
    full = np.asarray(streamed.result(timeout=30))
    assert sum(c.shape[0] for c in chunks) == full.shape[0] == n_rows
    print(f"streamed projection: {len(chunks)} chunks, "
          f"{full.nbytes} bytes total, byte-identical to blocking result: "
          f"{np.array_equal(np.concatenate(chunks), full)}")

    for tk in bulk:
        assert tk.result(timeout=60) is not None

    snap = server.snapshot()
    print(f"express p99 {snap['express_p99_ms']:.2f}ms | "
          f"bulk p99 {snap['bulk_p99_ms']:.2f}ms | "
          f"ticks={snap['ticks']} overlapped={snap['ticks_overlapped']} "
          f"deadline_misses={snap['deadline_misses']} "
          f"streams={snap['streams']}/{snap['stream_chunks']} chunks")


if __name__ == "__main__":
    main()
