"""Distributed relational analytics: rows sharded like parallel DRAM banks.

Runs the paper's aggregate / group-by / join queries through the shard_map
operators on every local device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see real
sharding), plus the MVCC snapshot story: a long-running analytical query is
isolated from concurrent transactional updates.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/relational_queries.py
      (REPRO_SMOKE=1 shrinks the relations so CI can run it in seconds)
"""

import os

import numpy as np

import jax

from repro.core import (
    RelationalMemoryEngine, RelationalTable, TableGeometry, benchmark_schema,
)
from repro.core import distributed as D
from repro.launch.mesh import make_mesh


SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main() -> None:
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 4_000 if SMOKE else 100_000
    table = RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )

    mesh = make_mesh((n_dev,), ("data",))
    words = D.pad_rows_to(table.words(), n_dev)

    # distributed Q3: per-bank fused select+aggregate, one scalar psum
    agg = D.dist_aggregate(words, mesh, agg_word=0, pred_word=3,
                           pred_op="lt", pred_k=0, valid_rows=n)
    expect = table.read_column_at("A1", np.arange(n))[
        table.read_column_at("A4", np.arange(n)) < 0
    ].sum()
    print(f"dist Q3: sum={float(agg[0]):.0f} count={float(agg[1]):.0f} "
          f"(expect {expect})")

    # distributed Q4: one-hot MXU contraction per bank + (G,2) psum
    s, c = D.dist_groupby(words, mesh, group_word=1, agg_word=0,
                          num_groups=32, valid_rows=n)
    print(f"dist Q4: {int((np.asarray(c) > 0).sum())} non-empty groups of 32")

    # distributed Q5: broadcast build side, probe locally
    n_r = 1 << 9 if SMOKE else 1 << 12
    r_cols = {cc.name: rng.integers(-100, 100, n_r).astype(np.int32)
              for cc in schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)
    r_table = RelationalTable.from_columns(schema, r_cols)
    s_geom = TableGeometry.from_schema(schema, ["A1", "A2"], n)
    r_geom = TableGeometry.from_schema(schema, ["A2", "A3"], n_r)
    _, _, matched = D.dist_join(
        words, D.pad_rows_to(r_table.words(), n_dev), mesh, s_geom, r_geom,
        s_key_word=1, s_val_word=0, r_key_word=0, r_val_word=1,
        s_valid_rows=n, r_valid_rows=n_r,
    )
    print(f"dist Q5: {int(np.asarray(matched)[:n].sum())} of {n} matched")

    # MVCC: analytics on a snapshot are isolated from concurrent updates
    engine = RelationalMemoryEngine()
    ts = table.now()
    live_rows = np.nonzero(table.snapshot_mask())[0]
    table.update(live_rows[:1000], {"A1": np.full(1000, 10**6, np.int32)})
    frozen = engine.register(table, ("A1",), snapshot_ts=ts)
    a1 = np.asarray(frozen.column("A1"))
    assert (a1 >= 10**6).sum() == 0, "snapshot leaked updated rows!"
    print(f"MVCC: snapshot@{ts} sees {len(a1)} rows, none updated; "
          f"live view sees {int((np.asarray(engine.register(table, ('A1',)).column('A1')) >= 10**6).sum())} updated")

    # sharded backend: same ops, one fused pass per shard, only reduced
    # partials cross the interconnect (pass mesh=mesh on a real mesh)
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import AggregateOp

    sharded = ShardedEngine(num_shards=max(n_dev, 4))
    (sum_c,) = sharded.execute_many([AggregateOp(table, "A2")])
    ref = engine.execute_many([AggregateOp(table, "A2")])[0]
    assert float(sum_c[0]) == float(ref[0]), "sharded != single-device"
    print(f"sharded: {sharded.num_shards} shards, sum={float(sum_c[0]):.0f}, "
          f"collective_bytes={sharded.stats.bytes_collective} "
          f"(dram_bytes={sharded.stats.bytes_from_dram})")


if __name__ == "__main__":
    main()
