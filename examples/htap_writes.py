"""Live writes through the QueryServer — the HTAP write path in one sitting.

One relation takes inserts, updates, and deletes *while* analytical clients
query it: write tickets and read plans share the admission queue, each tick
applies its writes first and serves every read from that post-write snapshot,
and the engine ships only the write delta host→device (tail-chunk uploads for
appends, patched timestamp words for deletes/updates) while hot views survive
appends via incremental tail scans.  A reader pinned to an old snapshot gets
byte-identical results throughout (MVCC, paper §4).

Run:  PYTHONPATH=src python examples/htap_writes.py
      (REPRO_SMOKE=1 shrinks the table for the CI docs-and-examples leg)
"""

import os

import numpy as np

from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema, plan
from repro.serve import QueryServer

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main() -> None:
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)  # 16 × int32 columns
    n = 2_000 if SMOKE else 20_000
    table = RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-1000, 1000, n).astype(np.int32)
         for c in schema.columns},
    )
    engine = RelationalMemoryEngine()
    server = QueryServer(engine, snapshot_reads=True)

    # make the table device-resident and one dashboard view hot
    _ = engine.aggregate(table, "A1")
    dashboard = engine.register(table, ("A1", "A2"))
    _ = dashboard.packed()
    engine.stats.reset()

    # a long-running reader pins the pre-write snapshot
    pinned_ts = table.now()
    pinned_before = engine.aggregate(table, "A1", snapshot_ts=pinned_ts)

    # one serving tick: three writes interleaved with three reads
    fresh = {c.name: rng.integers(-1000, 1000, 64).astype(np.int32)
             for c in schema.columns}
    ins = server.submit_insert(table, fresh, client="ingest")
    upd = server.submit_update(table, np.arange(8),
                               {"A2": np.full(8, 10_000, np.int32)},
                               client="ingest")
    dele = server.submit_delete(table, np.arange(100, 104), client="ingest")
    total = server.submit(plan(table).sum("A1"), client="analyst")
    hot = server.submit(plan(table).filter("A2", "gt", 5_000).count("A2"),
                        client="analyst")
    means = server.submit(plan(table).groupby("A4", "A1", "avg", 16),
                          client="analyst")
    server.run_tick()

    print(f"writes: inserted {len(ins.result())} rows, "
          f"updated {len(upd.result())}, deleted 4 (ticket: {dele.result()})")
    print(f"reads (post-write snapshot): sum={total.result():.0f}, "
          f"rows with A2>5000: {hot.result():.0f}, "
          f"group means shape {np.asarray(means.result()).shape}")

    # the pinned reader is byte-stable across all of it
    assert engine.aggregate(table, "A1", snapshot_ts=pinned_ts) == pinned_before
    print(f"pinned reader @ts={pinned_ts}: unchanged "
          f"(sum={pinned_before[0]:.0f}, count={pinned_before[1]:.0f})")

    # the hot view survived the append: tail delta scan, not a rebuild
    _ = engine.register(table, ("A1", "A2")).packed()
    s = engine.stats
    print(f"engine PMU: uploads={s.uploads} (delta={s.delta_uploads}), "
          f"bytes_uploaded={s.bytes_uploaded} "
          f"(delta={s.bytes_uploaded_delta} — vs {table.nbytes()} resident), "
          f"delta_hits={s.delta_hits}")
    assert s.bytes_uploaded == s.bytes_uploaded_delta  # O(delta), never O(T)
    assert s.delta_hits >= 1
    print("HTAP write path complete: O(delta) uploads, surviving hot views, "
          "snapshot-isolated readers.")


if __name__ == "__main__":
    main()
