"""Sharded-serving scaling: one fused pass per shard, reduction-only traffic.

Sweeps the mesh width (1/2/4/8 shards) over the same mixed tick — project +
filter + aggregate + group-by in ONE fused scan per shard — and reports the
interconnect accounting next to the scan itself: ``collective_bytes`` is the
cross-shard reduction traffic (aggregate/group-by partials), ``dram_bytes``
the per-bank streaming.  Real devices are used when the process has them
(``--xla_force_host_platform_device_count``); otherwise every shard is a
logical bank on the one CPU device — the datapath and the charging rules are
identical, which is what the gate cares about.

The figure also *checks* the paper's interconnect claim rather than just
plotting it: the same request set at 2x the rows must produce byte-identical
collective traffic (O(results), not O(rows)) — a violation raises, so CI
smoke catches any accounting or datapath change that starts shipping rows
across shards.
"""

import jax

from repro.core.requests import AggregateOp, FilterOp, GroupByOp, ProjectOp

from . import common
from .common import emit, make_benchmark_table, timeit

SHARD_COUNTS = (1, 2, 4, 8)


def _engine(shards: int):
    from repro.core.distributed import ShardedEngine

    if len(jax.devices()) >= shards > 1:
        from repro.launch.mesh import make_mesh

        return ShardedEngine(mesh=make_mesh((shards,), ("data",)))
    return ShardedEngine(num_shards=shards)


def _mixed_tick_ops(eng, t):
    return [
        ProjectOp(eng.register(t, ("A1", "A5"))),
        FilterOp(eng.register(t, ("A1", "A3")), "A3", "gt", 10),
        AggregateOp(t, "A1", pred_col="A2", pred_op="lt", pred_k=0),
        GroupByOp(t, "A2", "A1", 16),
    ]


def _collective_bytes(shards: int, n_rows: int) -> int:
    eng = _engine(shards)
    t = make_benchmark_table(n_rows=n_rows, seed=3)
    eng.execute_many(_mixed_tick_ops(eng, t))
    return eng.stats.bytes_collective


def run() -> None:
    n_rows = common.bench_rows(44_000)
    for shards in SHARD_COUNTS:
        t = make_benchmark_table(n_rows=n_rows, seed=3)
        eng = _engine(shards)
        ops = _mixed_tick_ops(eng, t)
        eng.execute_many(ops)  # cold pass: uploads + accounting
        coll = eng.stats.bytes_collective
        coll_ops = eng.stats.collective_ops
        dram = eng.stats.bytes_from_dram
        us = timeit(lambda: eng.execute_many(ops), iters=3)
        emit(
            f"fig_dist/shards{shards}",
            us,
            f"shards={shards},collective_bytes={coll},"
            f"collective_ops={coll_ops},dram_bytes={dram},"
            f"qps={1e6 / max(us, 1e-9):.1f}",
        )

    # interconnect traffic is a function of RESULT size only: double the
    # rows, byte-identical collectives (per-request reduced partials)
    small = _collective_bytes(4, max(n_rows // 2, 64))
    large = _collective_bytes(4, n_rows)
    if small != large:
        raise AssertionError(
            f"collective bytes scaled with rows ({small} -> {large}); "
            "reductions must cross the interconnect, never rows"
        )
    emit("fig_dist/collective_o_results", 0.0,
         f"collective_bytes={large},rows={n_rows}")
