"""Fig. 11: Q2/Q3/Q4 with 4-byte columns and growing row size.

The paper's point: RME latency stays flat (it touches only the enabled
columns) while the direct row-wise path degrades with row width — cache
pollution in hardware, extra bytes shipped here.  `derived` carries the
bytes ratio, which is the hardware-independent form of the result.
"""

from repro.core import TableGeometry, bytes_moved
from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    for row_bytes in (32, 64, 128, 256):
        t = make_benchmark_table(row_bytes=row_bytes, col_bytes=4, n_rows=n_rows)
        eng = fresh_engine()
        cs = ops.make_colstore(t, list(t.schema.names))
        geom = TableGeometry.from_schema(t.schema, ["A1", "A3"], n_rows)
        ratio = bytes_moved(geom)["row_wise"] / max(bytes_moved(geom)["rme"], 1)

        us = timeit(lambda: ops.q3_select_aggregate(eng, t, "A2", "A4", -800),
                    iters=3)
        emit(f"fig11/q3_r{row_bytes:03d}_rme", us, f"bytes_ratio={ratio:.1f}")
        us = timeit(lambda: ops.q3_select_aggregate(eng, t, "A2", "A4", -800,
                                                    path="row", colstore=cs), iters=3)
        emit(f"fig11/q3_r{row_bytes:03d}_row", us, "")

        us = timeit(lambda: ops.q2_select_project(eng, t, "A1", "A3", 100),
                    iters=3)
        emit(f"fig11/q2_r{row_bytes:03d}_rme", us, "")
        us = timeit(lambda: ops.q2_select_project(eng, t, "A1", "A3", 100,
                                                  path="row", colstore=cs), iters=3)
        emit(f"fig11/q2_r{row_bytes:03d}_row", us, "")

        us = timeit(lambda: ops.q4_groupby_avg(eng, t, "A1", "A3", "A2", -800, 64),
                    iters=3)
        emit(f"fig11/q4_r{row_bytes:03d}_rme", us, "")
        us = timeit(lambda: ops.q4_groupby_avg(eng, t, "A1", "A3", "A2", -800, 64,
                                               path="row", colstore=cs), iters=3)
        emit(f"fig11/q4_r{row_bytes:03d}_row", us, "")
