"""Fault-tolerance figure: instrumented-path overhead + recovery cost per site.

Two claims of the reliability layer (docs/reliability.md), both **checked**
in-module rather than just plotted, so CI smoke fails on a drift:

1. *Fault-free overhead* — the injection hooks (``faults.maybe_fault`` at
   every site) are a dict lookup when no plan is installed.  The same mixed
   tick with hooks idle vs. a non-matching plan installed must move
   byte-identical traffic (``overhead_delta_bytes == 0``, a deterministic
   gate metric) and cost at most 5% wall time (min-of-N interleaved, a hard
   in-module assert — the figure raises, like fig_dist_scaling's
   O(results) collective check).

2. *Recovery cost per site* — one scenario per injection site, each
   verifying the recovered answer is byte-identical to a fault-free run
   (or typed, for quarantine paths) and emitting the exact counters the
   recovery burned: ``retries``/``failovers`` (deterministic exact counts,
   like the SLO counters) and ``failover_bytes``/``wal_bytes``
   (deterministic byte metrics, gated from day one by the ``*_bytes``
   rule).
"""

import time

import numpy as np

from repro.core import (
    FaultPlan, RelationalMemoryEngine, RelationalTable, WriteAheadLog,
    fault_plan, plan,
)
from repro.core.distributed import ShardedEngine
from repro.core.requests import AggregateOp, FilterOp, GroupByOp, ProjectOp
from repro.serve.query_server import QueryServer

from . import common
from .common import emit, make_benchmark_table

OVERHEAD_PAIRS = 25
OVERHEAD_LIMIT = 1.05  # the ≤5% fault-free instrumentation budget


def _mixed_ops(eng, t):
    return [
        ProjectOp(eng.register(t, ("A1", "A5"))),
        FilterOp(eng.register(t, ("A1", "A3")), "A3", "gt", 10),
        AggregateOp(t, "A1"),
        GroupByOp(t, "A2", "A1", 16),
    ]


def _assert_same(a, b, what):
    for x, y in zip(a, b):
        xs = x if isinstance(x, tuple) else (x,)
        ys = y if isinstance(y, tuple) else (y,)
        for xi, yi in zip(xs, ys):
            if not np.array_equal(np.asarray(xi), np.asarray(yi)):
                raise AssertionError(f"{what}: recovered result diverged "
                                     "from the fault-free run")


def _never_fires():
    # a real installed plan whose spec can never match: the hooks take
    # their slow path (context assembly + spec scan) on every hit
    return FaultPlan().inject("upload", times=None, table=-1)


def bench_overhead(n_rows: int) -> None:
    t = make_benchmark_table(n_rows=n_rows, seed=5)
    eng_idle = RelationalMemoryEngine(revision="xla")
    eng_inst = RelationalMemoryEngine(revision="xla")
    ops_idle = _mixed_ops(eng_idle, t)
    ops_inst = _mixed_ops(eng_inst, t)

    out_idle = eng_idle.execute_many(ops_idle)  # cold: uploads
    with fault_plan(_never_fires()):
        out_inst = eng_inst.execute_many(ops_inst)
    _assert_same(out_idle, out_inst, "fault-free overhead")
    delta_bytes = abs(eng_inst.stats.bytes_from_dram
                      - eng_idle.stats.bytes_from_dram)
    if delta_bytes:
        raise AssertionError(
            f"idle fault hooks changed DRAM traffic by {delta_bytes} bytes")

    # wall overhead: the SAME warm engine with the real hooks (no plan
    # installed — the production configuration) vs the hooks stubbed to a
    # no-op, i.e. the un-instrumented path.  Each sample batches K serves
    # (a sub-millisecond warm serve alone is all scheduler noise); arms
    # interleave and take the min sample, so a background stall hits both
    # arms alike.
    from repro.core import faults

    batch = 20 if common.SMOKE else 4
    real_hook = faults.maybe_fault

    def _sample(stubbed: bool) -> float:
        faults.maybe_fault = ((lambda site, **ctx: None) if stubbed
                              else real_hook)
        try:
            t0 = time.perf_counter()
            for _ in range(batch):
                eng_inst.execute_many(ops_inst)
            return time.perf_counter() - t0
        finally:
            faults.maybe_fault = real_hook

    _sample(False)  # warmup
    pairs = []
    bare, inst = float("inf"), float("inf")
    for i in range(OVERHEAD_PAIRS):
        first_stubbed = i % 2 == 0  # alternate order: drift cancels
        a = _sample(first_stubbed)
        b = _sample(not first_stubbed)
        bare_i, inst_i = (a, b) if first_stubbed else (b, a)
        bare = min(bare, bare_i)
        inst = min(inst, inst_i)
        pairs.append(inst_i / max(bare_i, 1e-12))
    # two robust estimators of the true ratio, each noisy differently:
    # median of adjacent-pair ratios (a background stall lands on both
    # members of its pair, or skews one odd pair the median drops) and
    # best-vs-best (scheduler noise only ever ADDS time, so the min sample
    # per arm is the cleanest single observation).  Noise inflates both
    # upward; a genuine regression inflates both — gate on the smaller.
    ratio = min(float(np.median(pairs)), inst / max(bare, 1e-12))
    if ratio > OVERHEAD_LIMIT:
        raise AssertionError(
            f"fault-free instrumentation overhead {ratio:.3f}x exceeds "
            f"the {OVERHEAD_LIMIT:.2f}x budget")
    emit(
        "fig_fault/overhead",
        inst / batch * 1e6,
        f"rows={n_rows},overhead_delta_bytes={delta_bytes},"
        f"overhead_pct={max(ratio - 1.0, 0.0) * 100:.2f}",
    )


def _timed_drain(srv):
    t0 = time.perf_counter()
    srv.drain()
    return (time.perf_counter() - t0) * 1e6


def bench_server_site(site, n_rows, make_query, **inject_kw) -> None:
    """One server-recovered site: transient fault, bounded retry, result
    byte-identical to a fault-free serve of the same plan."""
    t = make_benchmark_table(n_rows=n_rows, seed=6)
    ref_srv = QueryServer(RelationalMemoryEngine(revision="xla"))
    tk = make_query(ref_srv, t)
    ref_srv.drain()
    ref = tk.result()

    srv = QueryServer(RelationalMemoryEngine(revision="xla"))
    with fault_plan(FaultPlan().inject(site, **inject_kw)):
        tk = make_query(srv, t)
        us = _timed_drain(srv)
    _assert_same([tk.result()], [ref], f"site {site}")
    snap = srv.snapshot()
    emit(
        f"fig_fault/{site}",
        us,
        f"rows={n_rows},retries={snap['retries']},served={snap['served']},"
        f"poisoned={snap['poisoned']}",
    )


def bench_shard_sites(n_rows: int) -> None:
    t = make_benchmark_table(n_rows=n_rows, seed=7)
    ops = lambda: [AggregateOp(t, "A1"), GroupByOp(t, "A2", "A1", 16)]
    ref = RelationalMemoryEngine(revision="xla").execute_many(ops())

    # transient shard fault: one bounded retry, zero bytes re-shipped
    eng = ShardedEngine(num_shards=2, revision="xla")
    with fault_plan(FaultPlan().inject("shard_pass", shard=1)):
        t0 = time.perf_counter()
        out = eng.execute_many(ops())
        us = (time.perf_counter() - t0) * 1e6
    _assert_same(out, ref, "shard_pass transient")
    emit(
        "fig_fault/shard_pass",
        us,
        f"rows={n_rows},retries={eng.stats.retries},"
        f"failovers={eng.stats.failovers},"
        f"failover_bytes={eng.stats.bytes_failover}",
    )

    # permanent shard fault: the shard's chunks re-execute on the root
    # device — the recovery cost is exactly the shard's resident bytes
    eng = ShardedEngine(num_shards=2, revision="xla")
    with fault_plan(FaultPlan().inject("shard_pass", kind="permanent",
                                       times=None, shard=0)):
        t0 = time.perf_counter()
        out = eng.execute_many(ops())
        us = (time.perf_counter() - t0) * 1e6
    _assert_same(out, ref, "shard_pass failover")
    emit(
        "fig_fault/shard_failover",
        us,
        f"rows={n_rows},failovers={eng.stats.failovers},"
        f"failover_bytes={eng.stats.bytes_failover},"
        f"quarantined={sum(h == 'quarantined' for h in eng.shard_health())}",
    )

    # collective combine: reduction-only retry (no re-scan, no re-upload)
    eng = ShardedEngine(num_shards=2, revision="xla")
    with fault_plan(FaultPlan().inject("collective_combine")):
        t0 = time.perf_counter()
        out = eng.execute_many(ops())
        us = (time.perf_counter() - t0) * 1e6
    _assert_same(out, ref, "collective_combine")
    emit(
        "fig_fault/collective_combine",
        us,
        f"rows={n_rows},retries={eng.stats.retries},"
        f"failover_bytes={eng.stats.bytes_failover}",
    )


def bench_breaker(n_rows: int) -> None:
    """Persistent lowering failure: the breaker trips the route to the XLA
    fallback — every serve still answers byte-identically."""
    t = make_benchmark_table(n_rows=n_rows, seed=8)
    ops = lambda: [AggregateOp(t, "A1"), GroupByOp(t, "A2", "A1", 16)]
    ref = RelationalMemoryEngine(revision="xla").execute_many(ops())
    eng = RelationalMemoryEngine(revision="mlp", breaker_threshold=2,
                                 breaker_cooldown=4)
    with fault_plan(FaultPlan().inject("lowering", times=None, op="scan")):
        t0 = time.perf_counter()
        for _ in range(4):
            _assert_same(eng.execute_many(ops()), ref, "lowering breaker")
        us = (time.perf_counter() - t0) * 1e6 / 4
    snap = eng.breaker.snapshot()
    emit(
        "fig_fault/lowering",
        us,
        f"rows={n_rows},breaker_trips={snap['breaker_trips']},"
        f"breaker_fallbacks={snap['breaker_fallbacks']},"
        f"breaker_open={snap['breaker_open']}",
    )


def bench_join_build(n_rows: int) -> None:
    """Transient fault while hash-partitioning the build side: the server's
    bounded retry rebuilds; the probe answer stays byte-identical."""
    from repro.core import operators as ops

    t = make_benchmark_table(n_rows=n_rows, seed=10)
    rt = make_benchmark_table(n_rows=max(n_rows // 8, 32), seed=11)
    q = plan(t).join(rt, key="A2", left_proj="A1", right_proj="A3").build()

    ops.clear_join_build_cache()
    ref_srv = QueryServer(RelationalMemoryEngine(revision="xla"))
    tk = ref_srv.submit(q)
    ref_srv.drain()
    ref = tk.result()

    ops.clear_join_build_cache()
    srv = QueryServer(RelationalMemoryEngine(revision="xla"))
    with fault_plan(FaultPlan().inject("join_build")):
        tk = srv.submit(q)
        us = _timed_drain(srv)
    out = tk.result()
    _assert_same([out.s_proj, out.r_proj, out.matched],
                 [ref.s_proj, ref.r_proj, ref.matched], "join_build")
    snap = srv.snapshot()
    emit(
        "fig_fault/join_build",
        us,
        f"rows={n_rows},retries={snap['retries']},served={snap['served']}",
    )


def bench_wal(n_rows: int) -> None:
    """WAL durability: log a write workload, crash (corrupt the tail),
    recover, and verify the recovered table is byte-identical to the
    surviving prefix state.  ``wal_bytes`` is the log's exact footprint."""
    from repro.core import benchmark_schema

    rng = np.random.default_rng(9)
    schema = benchmark_schema(64, 4)
    schema_cols = lambda n: {
        c.name: rng.integers(-100, 100, n).astype(np.int32)
        for c in schema.columns
    }
    t = RelationalTable.from_columns(schema, schema_cols(n_rows))
    wal = WriteAheadLog()
    srv = QueryServer(RelationalMemoryEngine(revision="xla"), wal=wal)
    srv.submit_insert(t, schema_cols(16))
    srv.submit_delete(t, np.array([1, 3], np.int64))
    srv.drain()
    pre_update = t._words[: t.row_count].copy()
    srv.submit_update(t, np.array([0], np.int64),
                      {"A1": np.array([7], np.int32)})
    srv.drain()
    pre_crash = t._words[: t.row_count].copy()

    t0 = time.perf_counter()
    recovered = RelationalTable.recover(wal, t.uid)
    us = (time.perf_counter() - t0) * 1e6
    if not np.array_equal(recovered._words[: recovered.row_count], pre_crash):
        raise AssertionError("WAL replay diverged from the live table")
    # crash mid-flush: the torn tail record (the update) is dropped, and
    # recovery lands byte-exactly on the state before it
    torn = RelationalTable.recover(wal.corrupted_tail(), t.uid)
    if not np.array_equal(torn._words[: torn.row_count], pre_update):
        raise AssertionError("corrupted-tail recovery lost the wrong suffix")
    emit(
        "fig_fault/wal_replay",
        us,
        f"rows={n_rows},wal_records={wal.record_count},"
        f"wal_bytes={wal.nbytes}",
    )


def run() -> None:
    n_rows = common.bench_rows(44_000)
    bench_overhead(n_rows)
    bench_server_site("upload", n_rows,
                      lambda srv, t: srv.submit(plan(t).project("A1", "A4")))
    bench_server_site("scan_launch", n_rows,
                      lambda srv, t: srv.submit(plan(t).aggregate("A1")))
    bench_server_site(
        "stream_chunk", n_rows,
        lambda srv, t: srv.submit(plan(t).project("A1", "A4"), stream=True,
                                  stream_chunk_rows=max(n_rows // 4, 64)))
    bench_join_build(n_rows)
    bench_shard_sites(n_rows)
    bench_breaker(n_rows)
    bench_wal(min(n_rows, 4_000))


if __name__ == "__main__":
    run()
