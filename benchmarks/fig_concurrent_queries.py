"""Concurrent query serving: N clients against one table, served vs per-query.

The QueryServer admission-queues logical plans from many clients and serves
each tick's same-table work from **one** shared scan (plus fused aggregates
enqueued via ``aggregate_async``).  This figure sweeps 1/4/16/64 concurrent
clients, each submitting ``ROUNDS`` projection-shaped queries over the shared
relation (column groups cycle through the Q0–Q5 shapes), and reports per path:

* ``qps``   — client queries completed per second of serving wall time
* row-store bytes — ``bytes_from_dram + bytes_uploaded`` for the whole batch

``per_query`` executes the identical compiled plans one at a time on the same
engine (the pre-serving dispatch model: every query pays its own row-store
pass); ``served`` pushes them through the server, where each tick's batch
coalesces into one union-geometry pass.  Both sides run the paper's 2 MB
reorganization SPM — under multi-tenant traffic the distinct packed groups
overflow it, so per-query execution keeps re-scanning while the shared scan
pays the stream once per tick.  That cache-pressure regime is the point: it
is where serving-level coalescing, not cache warm-up, carries the win.  The
reorg cache starts cold for each measured batch on both sides.
"""

from repro.core import compile_plan, plan
from repro.serve import QueryServer

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

# bigger than the other figures on purpose: serving overhead (tickets, queue,
# compile) is fixed per query, so the scan-sharing win is visible once the
# row store is large enough that the scans dominate — 200k rows = 12.8 MB
# against the 2 MB SPM
N_ROWS = 200_000
ROUNDS = 3  # queries per client per measured batch
CLIENT_COUNTS = (1, 4, 16, 64)

# the column groups Q0–Q5 touch on the probe table (fig9/fig10 shapes); the
# (client, round) grid cycles through them, so 16 clients cover every group
# several times — duplicates inside one tick dedupe in the shared scan
VIEW_GROUPS = (
    ("A1",),                      # Q0's scan
    ("A1", "A2", "A3", "A4"),     # Q1: project A1..A4
    ("A1", "A3"),                 # Q2: A1 WHERE A3
    ("A2", "A4"),                 # Q3: SUM(A2) WHERE A4
    ("A1", "A2", "A3"),           # Q4: AVG(A1) WHERE A3 GROUP BY A2
    ("A1", "A2"),                 # Q5: S-side {proj, key}
    ("A5", "A9"),
    ("A2", "A6", "A7"),
)


def _row_store_bytes(stats) -> int:
    return stats.bytes_from_dram + stats.bytes_uploaded


def _client_plans(table, n_clients: int):
    return [
        plan(table).project(*VIEW_GROUPS[(i + r) % len(VIEW_GROUPS)])
        for r in range(ROUNDS)
        for i in range(n_clients)
    ]


def run() -> None:
    t = make_benchmark_table(n_rows=bench_rows(N_ROWS))

    for n_clients in CLIENT_COUNTS:
        plans = _client_plans(t, n_clients)

        # ---- byte accounting (one cold batch each way) --------------------
        solo = fresh_engine()
        for p in plans:
            compile_plan(p, solo).run()
        served_eng = fresh_engine()
        server = QueryServer(served_eng, max_batch=n_clients)
        tickets = [
            server.submit(p, client=f"c{i % n_clients:02d}")
            for i, p in enumerate(plans)
        ]
        server.drain()
        for tk in tickets:
            tk.result(timeout=120)
        solo_bytes = _row_store_bytes(solo.stats)
        served_bytes = _row_store_bytes(served_eng.stats)
        # snapshot the accounting batch's serving stats *before* the timing
        # loops below push more batches through the same server — the emitted
        # ratio/savings must describe the same single batch as the byte counts
        shared_ratio = server.stats.shared_scan_ratio
        bytes_saved = server.stats.bytes_saved

        # ---- throughput (cache cold per measured batch, row store resident)
        def per_query():
            solo.cache.reset()
            return [compile_plan(p, solo).run() for p in plans]

        def served():
            served_eng.cache.reset()
            tks = [server.submit(p) for p in plans]
            server.drain()
            return [tk.result(timeout=120) for tk in tks]

        us_solo = timeit(per_query, iters=5)
        us_served = timeit(served, iters=5)
        qps_solo = len(plans) / (us_solo / 1e6)
        qps_served = len(plans) / (us_served / 1e6)
        d = (f"clients={n_clients},queries={len(plans)},"
             f"solo_bytes={solo_bytes},served_bytes={served_bytes},"
             f"bytes_ratio={solo_bytes / max(served_bytes, 1):.1f}")
        emit(f"fig_concurrent/c{n_clients:02d}_per_query", us_solo,
             d + f",qps={qps_solo:.0f}")
        emit(f"fig_concurrent/c{n_clients:02d}_served", us_served,
             d + f",qps={qps_served:.0f},"
             f"speedup={us_solo / max(us_served, 1e-9):.2f}x,"
             f"shared_ratio={shared_ratio:.2f},"
             f"bytes_saved={bytes_saved}")
