"""LM substrate benchmark: train-step and decode-step wall time per arch
(reduced configs — CPU-runnable, exercising the real framework code paths).
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state

from . import common
from .common import emit, timeit

B, S = 4, 128


def run() -> None:
    rng = np.random.default_rng(0)
    # smoke probes a single architecture; the real bench sweeps all of them
    archs = ARCH_NAMES[:1] if common.SMOKE else ARCH_NAMES
    for arch in archs:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        if cfg.is_encdec:
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(0, .5, (B, S // cfg.enc_subsample, cfg.d_model)),
                jnp.float32)
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        elif cfg.embed_inputs:
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        else:
            batch["embeds"] = jnp.asarray(rng.normal(0, .5, (B, S, cfg.d_model)),
                                          jnp.float32)
        step = jax.jit(make_train_step(model, AdamWConfig()))
        us = timeit(lambda: step(state, batch)[1]["loss"], iters=3)
        emit(f"lm/{arch}_train_step", us, f"tok_per_s={B * S / (us / 1e6):.0f}")

        if cfg.is_encdec or cfg.embed_inputs:
            pre = {k: v for k, v in batch.items() if k != "labels"}
            logits, cache = jax.jit(lambda p, b: model.prefill(p, b, S + 16))(
                state["params"], pre)
            dstep = jax.jit(model.decode_step)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            us = timeit(lambda: dstep(state["params"], cache, tok,
                                      jnp.asarray(S, jnp.int32))[0], iters=5)
            emit(f"lm/{arch}_decode_step", us,
                 f"tok_per_s={B / (us / 1e6):.0f}")
