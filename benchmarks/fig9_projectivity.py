"""Fig. 9: projectivity sweep 1..11 columns (of 16) — the paper's Figure 1
economics made concrete: row-wise cost is flat (always ships everything),
columnar cost grows with tuple reconstruction, RME tracks the useful bytes.
"""


from repro.core import TableGeometry, bytes_moved
from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    t = make_benchmark_table(n_rows=n_rows)
    for k in range(1, 12):
        cols = tuple(f"A{i + 1}" for i in range(k))
        geom = TableGeometry.from_schema(t.schema, cols, n_rows)
        eng = fresh_engine()
        cs = ops.make_colstore(t, cols)
        moved = bytes_moved(geom)
        us_rme = timeit(lambda: (eng.reset(),
                                 ops.q1_project(eng, t, cols))[1], iters=3)
        us_row = timeit(lambda: ops.q1_project(eng, t, cols, path="row",
                                               colstore=cs), iters=3)
        us_col = timeit(lambda: ops.q1_project(eng, t, cols, path="col",
                                               colstore=cs), iters=3)
        d = (f"k={k},rme_bytes={moved['rme']},row_bytes={moved['row_wise']},"
             f"col_bytes={moved['columnar']}")
        emit(f"fig9/k{k:02d}_rme", us_rme, d)
        emit(f"fig9/k{k:02d}_direct_row", us_row, d)
        emit(f"fig9/k{k:02d}_direct_col", us_col, d)
