"""Mixed heterogeneous batches: one fused pass vs one pass per op kind.

The previous serving figure (``fig_concurrent_queries``) coalesced same-table
*projections* into one shared scan — but a realistic tick is mixed:
projections, predicated filters, fused aggregates, and group-bys against the
same relation.  Before the heterogeneous one-pass scan, each op kind launched
its own full sweep of the row store (N kinds ⇒ N passes); now every kind of
same-table work rides one ``rme_scan_multi`` pass.

This figure sweeps 16/64 concurrent clients, each submitting ``ROUNDS``
queries cycling through the four op kinds over Q0–Q5-shaped column groups,
and reports per path:

* ``qps``  — client queries completed per second of serving wall time
* row-store bytes — ``bytes_from_dram + bytes_uploaded`` for one cold batch
* ``one_pass_scans`` — engine shared scans recorded for a single mixed-kind
  same-table tick (the "scan once, answer everything" check: exactly 1)
* ``p50_ms`` / ``p95_ms`` — per-query serving latency percentiles

``per_kind`` executes the identical compiled plans one at a time on the same
engine — the pre-fusion dispatch model, where every aggregate/filter/group-by
pays its own row-store pass; ``fused`` pushes them through the
``QueryServer``, whose tick hands the whole batch to one ``execute_many``.
Both sides run the paper's 2 MB reorganization SPM and charge bus-beat bytes
with the same Eq. (3) union-geometry model, so the ratio is apples-to-apples.
"""

import numpy as np

from repro.core import RelationalTable, compile_plan, plan
from repro.serve import QueryServer

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 200_000
ROUNDS = 3  # queries per client per measured batch
CLIENT_COUNTS = (16, 64)
NUM_GROUPS = 32


def _client_plans(table, build_table, n_clients: int):
    """The (client, round) grid cycles through mixed op kinds over the
    Q0–Q5 column-group shapes — same-table, different operators, the
    device-offloaded Q5 join included (its probe-side scan rides the same
    fused pass as everything else on the table)."""
    t, rt = table, build_table
    shapes = [
        lambda: plan(t).project("A1", "A2", "A3", "A4"),          # Q1 scan
        lambda: plan(t).filter("A3", "gt", 0).project("A1"),      # Q2 filter
        lambda: plan(t).filter("A4", "lt", 10).sum("A2"),         # Q3 agg
        lambda: plan(t).groupby("A2", "A1", "avg", NUM_GROUPS),   # Q4 gby
        lambda: plan(t).join(rt, key="A2", left_proj="A1",
                             right_proj="A3"),                    # Q5 join
        lambda: plan(t).project("A5", "A9"),
        lambda: plan(t).filter("A7", "gt", -5).project("A2", "A6"),
        lambda: plan(t).sum("A8"),
        lambda: plan(t).groupby("A6", "A5", "sum", NUM_GROUPS),
    ]
    return [
        shapes[(i + r) % len(shapes)]()
        for r in range(ROUNDS)
        for i in range(n_clients)
    ]


def _make_build_table(table, n_r: int = 2_048):
    rng = np.random.default_rng(4)
    n_r = bench_rows(n_r, cap=256)
    cols = {c.name: rng.integers(-1000, 1000, n_r).astype(np.int32)
            for c in table.schema.columns}
    cols["A2"] = np.arange(n_r, dtype=np.int32)  # primary key
    return RelationalTable.from_columns(table.schema, cols)


def _row_store_bytes(stats) -> int:
    return stats.bytes_from_dram + stats.bytes_uploaded


def _one_pass_probe(table, build_table) -> int:
    """A single mixed-kind same-table tick on a fresh engine: how many scans?
    The join's probe-side projection must ride the same fused pass."""
    eng = fresh_engine()
    server = QueryServer(eng)
    server.submit(plan(table).project("A1", "A2"))
    server.submit(plan(table).filter("A3", "gt", 0).project("A1"))
    server.submit(plan(table).filter("A4", "lt", 10).sum("A2"))
    server.submit(plan(table).groupby("A2", "A1", "avg", NUM_GROUPS))
    server.submit(plan(table).join(build_table, key="A2", left_proj="A1",
                                   right_proj="A3"))
    server.run_tick()
    return eng.stats.shared_scans


def run() -> None:
    t = make_benchmark_table(n_rows=bench_rows(N_ROWS))
    rt = _make_build_table(t)
    one_pass = _one_pass_probe(t, rt)

    for n_clients in CLIENT_COUNTS:
        plans = _client_plans(t, rt, n_clients)

        # ---- byte accounting (one cold batch each way) --------------------
        solo = fresh_engine()
        for p in plans:
            compile_plan(p, solo).run()
        served_eng = fresh_engine()
        server = QueryServer(served_eng, max_batch=len(plans))
        tickets = [
            server.submit(p, client=f"c{i % n_clients:02d}")
            for i, p in enumerate(plans)
        ]
        server.drain()
        for tk in tickets:
            tk.result(timeout=120)
        solo_bytes = _row_store_bytes(solo.stats)
        served_bytes = _row_store_bytes(served_eng.stats)
        lat_ms = np.asarray([tk.latency_s for tk in tickets]) * 1e3
        p50, p95 = np.percentile(lat_ms, 50), np.percentile(lat_ms, 95)

        # ---- throughput (cache cold per measured batch, row store resident)
        def per_kind():
            solo.cache.reset()
            return [compile_plan(p, solo).run() for p in plans]

        def fused():
            served_eng.cache.reset()
            tks = [server.submit(p) for p in plans]
            server.drain()
            return [tk.result(timeout=120) for tk in tks]

        us_solo = timeit(per_kind, iters=5)
        us_fused = timeit(fused, iters=5)
        qps_solo = len(plans) / (us_solo / 1e6)
        qps_fused = len(plans) / (us_fused / 1e6)
        d = (f"clients={n_clients},queries={len(plans)},"
             f"solo_bytes={solo_bytes},served_bytes={served_bytes},"
             f"bytes_ratio={solo_bytes / max(served_bytes, 1):.1f},"
             f"one_pass_scans={one_pass}")
        emit(f"fig_mixed/c{n_clients:02d}_per_kind", us_solo,
             d + f",qps={qps_solo:.0f}")
        emit(f"fig_mixed/c{n_clients:02d}_fused", us_fused,
             d + f",qps={qps_fused:.0f},"
             f"speedup={us_solo / max(us_fused, 1e-9):.2f}x,"
             f"p50_ms={p50:.2f},p95_ms={p95:.2f},"
             f"tile={served_eng.stats.last_block_rows}")
