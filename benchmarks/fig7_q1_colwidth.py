"""Fig. 7: Q1 3-column projection vs column width — RME vs row vs columnar.

The paper's headline: RME beats direct row-wise access at every width and
approaches/beats pure columnar as width grows.  We report wall time plus the
exact bytes each path moves (the quantity the caches see).
"""

from repro.core import TableGeometry, bytes_moved
from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    for width in (4, 8, 12, 16):
        row_bytes = 16 * width
        t = make_benchmark_table(row_bytes=row_bytes, col_bytes=width,
                                 n_rows=n_rows)
        # three non-contiguous columns, mirroring offsets 0/24/48 of the paper
        cols = ("A1", "A7", "A13")
        geom = TableGeometry.from_schema(t.schema, cols, n_rows)
        eng = fresh_engine()
        cs = ops.make_colstore(t, cols)
        moved = bytes_moved(geom)

        eng.reset()
        us_cold = timeit(lambda: (eng.reset(), ops.q1_project(eng, t, cols))[1],
                         iters=3)
        view = eng.register(t, cols)
        _ = view.packed()
        us_hot = timeit(lambda: view.packed(), iters=5)
        us_row = timeit(lambda: ops.q1_project(eng, t, cols, path="row",
                                               colstore=cs), iters=3)
        us_col = timeit(lambda: ops.q1_project(eng, t, cols, path="col",
                                               colstore=cs), iters=3)
        d = (f"rme_bytes={moved['rme']},row_bytes={moved['row_wise']},"
             f"col_bytes={moved['columnar']}")
        emit(f"fig7/w{width:02d}_rme_cold", us_cold, d)
        emit(f"fig7/w{width:02d}_rme_hot", us_hot, d)
        emit(f"fig7/w{width:02d}_direct_row", us_row, d)
        emit(f"fig7/w{width:02d}_direct_col", us_col, d)
