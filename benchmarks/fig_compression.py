"""Compressed execution (§4): dict/FOR/string codecs vs the plain row store.

Three fused shapes, each run twice over byte-identical word layouts — once
with codecs attached (kernels on raw code words, predicate constants
translated at compile time, zero in-scan decodes) and once plain:

* a low-projectivity FOR aggregate (``SUM(F) WHERE K > k``),
* a high-projectivity filter+project over four columns (one a string),
* a string-keyed group-by.

Every row reports the Eq.(3) bus-beat bytes both ways; the encoded pass
must move **strictly fewer** row-store bytes than the plain pass (asserted
in-module and gated by ``perf_gate`` via the ``*_bytes`` and ``saving``
keys), and the results must be identical (the differential harness in
``tests/test_compressed_execution.py`` pins this at scale — here we spot
check the figure's own query set).
"""

import numpy as np

from repro.core.compression import DictCodec
from repro.core.requests import AggregateOp, FilterOp, GroupByOp
from repro.core.schema import Column, TableSchema
from repro.core.table import RelationalTable

from .common import bench_rows, emit, fresh_engine, timeit

N_ROWS = 40_000

STRINGS = np.array(
    ["amber", "basil", "cedar", "ember", "fig", "grove", "holly", "iris"]
)

ENC_SCHEMA = TableSchema((
    Column("K", "int32", codec="dict"),
    Column("F", "int32", codec="for"),
    Column("S", "str"),
    Column("V", "int32"),
    Column("P", "int32"),
))

# the plain twin: identical five-word layout, strings as raw codes
PLAIN_SCHEMA = TableSchema((
    Column("K", "int32"),
    Column("F", "int32"),
    Column("S", "int32"),
    Column("V", "int32"),
    Column("P", "int32"),
))


def _tables(n: int) -> tuple[RelationalTable, RelationalTable]:
    rng = np.random.default_rng(7)
    cols = {
        "K": rng.integers(0, 64, n).astype(np.int32),     # 64-entry dict
        "F": (500 + rng.integers(0, 128, n)).astype(np.int32),  # 7-bit deltas
        "S": rng.choice(STRINGS, n),
        "V": rng.integers(-1000, 1000, n).astype(np.int32),
        "P": rng.integers(-1000, 1000, n).astype(np.int32),
    }
    enc = RelationalTable.from_columns(ENC_SCHEMA, cols)
    plain = RelationalTable.from_columns(
        PLAIN_SCHEMA, dict(cols, S=DictCodec.fit(cols["S"]).encode(cols["S"]))
    )
    return enc, plain


def _measure(build_op, table):
    """(bytes_from_dram, bytes_saved, decodes, result, median us) of one
    fused op on a fresh engine — cold bytes, then resident-repeat timing."""
    eng = fresh_engine()
    res = eng.execute_many([build_op(eng, table)])[0]
    moved = eng.stats.bytes_from_dram
    saved = eng.stats.bytes_saved_compression
    decodes = eng.stats.decodes
    us = timeit(lambda: eng.execute_many([build_op(eng, table)]), iters=5)
    return moved, saved, decodes, res, us


def _pair(name: str, build_op, enc, plain, compare) -> None:
    e_bytes, e_saved, e_decodes, e_res, e_us = _measure(build_op, enc)
    p_bytes, _, _, p_res, p_us = _measure(build_op, plain)
    # the compressed pass must move strictly fewer row-store bytes and
    # never decode in-scan; and the two passes must agree
    assert e_bytes < p_bytes, (name, e_bytes, p_bytes)
    assert e_decodes == 0, (name, e_decodes)
    assert e_saved == p_bytes - e_bytes, (name, e_saved, p_bytes - e_bytes)
    compare(e_res, p_res)
    emit(
        f"fig_compression/{name}", e_us,
        f"encoded_bytes={e_bytes},plain_bytes={p_bytes},"
        f"saving={p_bytes / max(e_bytes, 1):.2f},"
        f"bytes_saved={e_saved},plain_us={p_us:.1f},"
        f"speedup={p_us / max(e_us, 1e-9):.2f}x",
    )


def run() -> None:
    n = bench_rows(N_ROWS)
    enc, plain = _tables(n)

    def agg(eng, t):
        return AggregateOp(t, "F", pred_col="K", pred_op="gt", pred_k=20)

    def agg_eq(a, b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    _pair("aggregate_for", agg, enc, plain, agg_eq)

    def filt(eng, t):
        return FilterOp(eng.register(t, ("K", "F", "S", "V")), "P", "lt", 0)

    def filt_eq(a, b):
        # plain columns and masks are byte-equal; K/F/S carry raw codes on
        # the encoded side, whose decode-equality the tier-1 harness owns
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        np.testing.assert_array_equal(np.asarray(a[0])[:, 3],
                                      np.asarray(b[0])[:, 3])

    _pair("filter_project", filt, enc, plain, filt_eq)

    def gbs(eng, t):
        return GroupByOp(t, "S", "V", len(STRINGS))

    def gbs_eq(a, b):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    _pair("groupby_string", gbs, enc, plain, gbs_eq)
