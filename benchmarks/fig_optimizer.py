"""Optimizer figure: what the logical rewrite layer buys in moved bytes.

Three rows, one per optimizer capability, each measured with the engine's
own PMU byte accounting (deterministic — gated exactly by ``perf_gate``):

* ``prune`` — a decorated aggregate (full-schema ``Project`` under ``Sum``)
  compiled raw materializes the whole projection before reducing; the
  ``prune-columns`` pass shrinks the scan to the aggregate column and the
  plan re-routes onto the fused-aggregate kernel.  ``raw_bytes`` vs
  ``opt_bytes`` is the DRAM traffic either way; the ratio must stay > 1.
* ``subsume`` — three projection tickets where the first covers the other
  two (word superset, no predicates).  Solo execution pays three scans;
  the batch route detects subsumption and serves all three from ONE
  covering scan (``subsumed=2``, ``shared_scans=1``).
* ``join_order`` — a two-join chain where the second build side is an
  order of magnitude smaller.  Cost-based ordering builds the cheap side
  first; the row reports the chosen order and both cold-build estimates.
"""

import numpy as np

from repro.core import (
    CompileOptions, Column, RelationalTable, TableSchema, compile_plan, plan,
)
from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 44_000


def _bytes_for(eng, pq) -> int:
    eng.cache.reset()
    eng.stats.reset()
    pq.run()
    return eng.stats.bytes_from_dram


def _emit_prune() -> None:
    # 8 columns: wide enough for pruning to matter, narrow enough that the
    # unoptimized full-schema projection still fits the enable-mask budget
    t = make_benchmark_table(row_bytes=32, n_rows=bench_rows(N_ROWS))
    eng = fresh_engine()
    q = plan(t).project(*t.schema.names).sum("A1")
    opt = compile_plan(q, eng)
    raw = compile_plan(q, eng, options=CompileOptions(optimize=False))
    assert abs(float(opt.run()) - float(raw.run())) < 1e-6
    opt_b = _bytes_for(eng, opt)
    raw_b = _bytes_for(eng, raw)
    us = timeit(opt.run, iters=3)
    emit("figopt/prune", us,
         f"raw_bytes={raw_b},opt_bytes={opt_b},"
         f"bytes_ratio={raw_b / max(opt_b, 1):.2f}")


def _emit_subsume() -> None:
    rng = np.random.default_rng(7)
    n = bench_rows(N_ROWS)
    schema = TableSchema(tuple(Column(f"C{i}", "int32") for i in range(24)))
    t = RelationalTable.from_columns(schema, {
        f"C{i}": rng.integers(-1000, 1000, n).astype(np.int32)
        for i in range(24)
    })
    eng = fresh_engine()
    groups = (("C0", "C1", "C2", "C3"),  # covers the other two tickets
              ("C0", "C2"),
              ("C1",))
    pqs = [compile_plan(plan(t).project(*g), eng) for g in groups]
    batch = [pq.ops[0] for pq in pqs]

    def solo():
        for op in batch:
            eng.execute_many([op])

    eng.cache.reset()
    eng.stats.reset()
    solo()
    solo_b = eng.stats.bytes_from_dram
    eng.cache.reset()
    eng.stats.reset()
    eng.execute_many(batch)
    batch_b = eng.stats.bytes_from_dram
    subsumed = eng.stats.subsumed_requests
    scans = eng.stats.shared_scans
    us = timeit(lambda: eng.execute_many(batch), iters=3)
    emit("figopt/subsume", us,
         f"solo_bytes={solo_b},batch_bytes={batch_b},subsumed={subsumed},"
         f"one_pass_scans={scans},"
         f"bytes_ratio={solo_b / max(batch_b, 1):.2f}")


def _emit_join_order() -> None:
    rng = np.random.default_rng(3)
    n = bench_rows(N_ROWS, cap=512)

    def tbl(cols: dict) -> RelationalTable:
        schema = TableSchema(tuple(Column(c, "int32") for c in cols))
        return RelationalTable.from_columns(
            schema, {c: v.astype(np.int32) for c, v in cols.items()})

    probe = tbl({"K1": rng.integers(0, n, n),
                 "K2": rng.integers(0, max(n // 10, 4), n),
                 "V": rng.integers(-1000, 1000, n)})
    big = tbl({"K1": np.arange(n), "B1": rng.integers(-9, 9, n)})
    small_n = max(n // 10, 4)
    small = tbl({"K2": np.arange(small_n), "B2": rng.integers(-9, 9, small_n)})

    eng = fresh_engine()
    ops.clear_join_build_cache()
    q = plan(probe).join(big, key="K1", left_proj="V", right_proj="B1") \
                   .join(small, key="K2", left_proj="V", right_proj="B2")
    pq = compile_plan(q, eng)
    order = "-".join(key for key, _, _ in pq.join_order)
    ests = {key: est for key, _, est in pq.join_order}
    us = timeit(lambda: (ops.clear_join_build_cache(), pq.run())[1], iters=3)
    emit("figopt/join_order", us,
         f"order={order},first_build_bytes={pq.join_order[0][2]},"
         f"second_build_bytes={pq.join_order[1][2]},"
         f"est_small_bytes={ests['K2']},est_big_bytes={ests['K1']}")


def run() -> None:
    _emit_prune()
    _emit_subsume()
    _emit_join_order()
