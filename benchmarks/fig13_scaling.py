"""Fig. 13: data-size scaling — Q1 over 4 columns, tables 4 MB → 64 MB.

The paper scales 32 MB → 2 GB on hardware; we scale within CPU-benchmark
budget and report the normalized RME/row-wise ratio, which the paper shows
to be flat (the reorg buffer's light-weight reset amortizes at every size —
here: the reorg cache holds none of these tables, every pass is cold).
"""

from repro.core import TableGeometry, bytes_moved
from repro.core import operators as ops

from . import common
from .common import emit, fresh_engine, make_benchmark_table, timeit


def run() -> None:
    cols = ("A1", "A5", "A9", "A13")
    # smoke probes one small size; the real figure scales 4 MB -> 64 MB
    sizes = (1,) if common.SMOKE else (4, 16, 64)
    for mb in sizes:
        n_rows = mb * (1 << 20) // 64
        t = make_benchmark_table(n_rows=n_rows)
        eng = fresh_engine(cache_bytes=2 << 20)  # 2 MB SPM << table size
        cs = ops.make_colstore(t, cols)
        geom = TableGeometry.from_schema(t.schema, cols, n_rows)
        us_rme = timeit(lambda: (eng.reset(),
                                 ops.q1_project(eng, t, cols))[1], iters=3)
        us_row = timeit(lambda: ops.q1_project(eng, t, cols, path="row",
                                               colstore=cs), iters=3)
        moved = bytes_moved(geom)
        emit(f"fig13/size{mb:03d}MB_rme", us_rme,
             f"norm_vs_row={us_rme / max(us_row, 1e-9):.3f},"
             f"rme_bytes={moved['rme']}")
        emit(f"fig13/size{mb:03d}MB_row", us_row,
             f"row_bytes={moved['row_wise']}")
