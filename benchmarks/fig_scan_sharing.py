"""Scan sharing: the Q0–Q5 column groups of one table, per-view vs batched.

The fig9/fig10 suites run the benchmark queries back-to-back over one
relation; each query registers its own ephemeral view, and the seed engine
paid a full row-store pass (and a host→device upload of the whole table) per
view.  The batch path coalesces the views and serves them all from **one**
stream — this figure reports both wall time and the engine's byte counters
(``bytes_from_dram`` bus-beat bytes + ``bytes_uploaded`` host→device
transfers) for the two strategies, plus the device-residency effect on
repeated fused aggregates.
"""

from repro.core import bytes_moved, merge_geometries

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 20_000

# the column groups Q0–Q5 touch on the probe table (fig9/fig10 shapes)
VIEW_GROUPS = (
    ("A1",),                      # Q0: SUM(A1)
    ("A1", "A2", "A3", "A4"),     # Q1: project A1..A4
    ("A1", "A3"),                 # Q2: A1 WHERE A3
    ("A2", "A4"),                 # Q3: SUM(A2) WHERE A4
    ("A1", "A2", "A3"),           # Q4: AVG(A1) WHERE A3 GROUP BY A2
    ("A1", "A2"),                 # Q5: S-side {proj, key}
)


def _row_store_bytes(stats) -> int:
    return stats.bytes_from_dram + stats.bytes_uploaded


def run() -> None:
    t = make_benchmark_table(n_rows=bench_rows(N_ROWS))

    # ---- byte accounting (one cold pass each way) -------------------------
    # per-view: independent materializations on the shipped engine — the
    # DeviceRowStore is left intact, so the table uploads once and each view
    # pays its own full scan
    solo = fresh_engine()
    for cols in VIEW_GROUPS:
        solo.cache.reset()
        solo.register(t, cols).packed()
    # seed-style: the pre-DeviceRowStore engine re-uploaded the row store on
    # every cold materialization (kept as a labeled extra, not the headline)
    seed = fresh_engine()
    for cols in VIEW_GROUPS:
        seed.cache.reset()
        seed.rowstore.clear()
        seed.register(t, cols).packed()
    batch = fresh_engine()
    views = [batch.register(t, cols) for cols in VIEW_GROUPS]
    batch.materialize_many(views)
    union = merge_geometries([v.geometry for v in views])

    solo_bytes = _row_store_bytes(solo.stats)
    seed_bytes = _row_store_bytes(seed.stats)
    batch_bytes = _row_store_bytes(batch.stats)
    ratio = solo_bytes / max(batch_bytes, 1)

    # ---- wall time (reorg cache cold each call; row store stays resident) --
    eng_a = fresh_engine()

    def per_view():
        eng_a.cache.reset()
        return [eng_a.register(t, cols).packed() for cols in VIEW_GROUPS]

    eng_b = fresh_engine()

    def shared_scan():
        eng_b.cache.reset()
        return eng_b.materialize_many(
            [eng_b.register(t, cols) for cols in VIEW_GROUPS]
        )

    us_solo = timeit(per_view, iters=5)
    us_batch = timeit(shared_scan, iters=5)
    d = (f"views={len(VIEW_GROUPS)},solo_bytes={solo_bytes},"
         f"batch_bytes={batch_bytes},bytes_ratio={ratio:.1f},"
         f"union_rme_bytes={bytes_moved(union)['rme']},"
         f"uploads_solo={solo.stats.uploads},uploads_batch={batch.stats.uploads}")
    emit("fig_scan_sharing/per_view", us_solo, d)
    emit("fig_scan_sharing/shared_scan", us_batch,
         d + f",speedup={us_solo / max(us_batch, 1e-9):.2f}x")
    emit("fig_scan_sharing/per_view_seed_reupload", 0.0,
         f"seed_bytes={seed_bytes},seed_vs_batch={seed_bytes / max(batch_bytes, 1):.1f}x,"
         f"uploads_seed={seed.stats.uploads}")

    # ---- device-resident aggregates: zero re-upload after the first -------
    eng_c = fresh_engine()
    eng_c.aggregate(t, "A1")  # first call pays the upload
    uploads_after_first = eng_c.stats.uploads
    us_agg = timeit(lambda: eng_c.aggregate(t, "A2", "A4", "lt", 0), iters=5)
    emit("fig_scan_sharing/agg_resident", us_agg,
         f"uploads_first={uploads_after_first},uploads_now={eng_c.stats.uploads}")
