"""Shared benchmark harness: timing + CSV rows (`name,us_per_call,derived`).

Smoke mode (``python -m benchmarks.run --smoke``, used as a CI job) shrinks
every figure to a seconds-long regression probe: ``bench_rows`` caps table
sizes and ``timeit`` drops to a single timed iteration.  The numbers are
meaningless as measurements — the point is that every kernel still lowers and
every figure's code path still runs, so lowering regressions fail in CI
instead of surfacing in full benchmark runs.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema

ROWS: list[tuple[str, float, str]] = []

SMOKE = False
SMOKE_ROW_CAP = 2_000
ROW_CAP: int | None = None  # non-smoke global cap (the nightly 50k regime)


def set_smoke(on: bool = True) -> None:
    """Flip the module-wide smoke switch (tiny tables, single iterations)."""
    global SMOKE
    SMOKE = on


def set_row_cap(n: int | None) -> None:
    """Cap every figure's table size without smoke-mode timing shortcuts —
    the nightly CI runs the full suite at ``--rows 50000`` so scheduled
    measurements finish in bounded time at a fixed, comparable scale."""
    global ROW_CAP
    ROW_CAP = n


def bench_rows(n: int, cap: int = SMOKE_ROW_CAP) -> int:
    """The figure's row count, capped in smoke mode (or by ``set_row_cap``)."""
    if SMOKE:
        return min(n, cap)
    if ROW_CAP is not None:
        return min(n, ROW_CAP)
    return n


def timeit(fn, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time in microseconds (device-synchronized)."""
    if SMOKE:
        iters, warmup = 1, 1
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def flush_rows() -> list[tuple[str, float, str]]:
    out = list(ROWS)
    ROWS.clear()
    return out


def make_benchmark_table(
    row_bytes: int = 64, col_bytes: int = 4, n_rows: int = 44_000, seed: int = 0
) -> RelationalTable:
    """The paper's synthetic benchmark relation (§6.2 defaults)."""
    rng = np.random.default_rng(seed)
    schema = benchmark_schema(row_bytes, col_bytes)
    if col_bytes == 4:
        cols = {
            c.name: rng.integers(-1000, 1000, n_rows).astype(np.int32)
            for c in schema.columns
        }
    else:
        cols = {
            c.name: rng.integers(0, 256, (n_rows, col_bytes)).astype(np.uint8)
            .view(np.dtype((np.bytes_, col_bytes))).reshape(-1)
            for c in schema.columns
        }
    return RelationalTable.from_columns(schema, cols)


def fresh_engine(revision: str = "xla", cache_bytes: int = 2 << 20):
    return RelationalMemoryEngine(revision=revision, cache_bytes=cache_bytes)
