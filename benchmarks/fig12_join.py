"""Fig. 12: Q5 hash join — RME projects only {key, payload} from both sides.

Matches the paper's setup: primary-key build side, ~50% of probe rows match,
CPU does the join itself (the RME only optimizes data movement).
"""

import numpy as np

from repro.core import RelationalTable, TableGeometry, benchmark_schema, bytes_moved
from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, timeit

N_S, N_R = 20_000, 4_096


def make_tables(row_bytes: int):
    rng = np.random.default_rng(0)
    n_s, n_r = bench_rows(N_S), bench_rows(N_R, cap=512)
    schema = benchmark_schema(row_bytes, 4)
    s_cols = {c.name: rng.integers(-1000, 1000, n_s).astype(np.int32)
              for c in schema.columns}
    s_cols["A2"] = rng.integers(0, 2 * n_r, n_s).astype(np.int32)  # ~50% match
    r_cols = {c.name: rng.integers(-1000, 1000, n_r).astype(np.int32)
              for c in schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)  # primary key
    return (RelationalTable.from_columns(schema, s_cols),
            RelationalTable.from_columns(schema, r_cols))


def run() -> None:
    for row_bytes in (32, 64, 128, 256):
        s, r = make_tables(row_bytes)
        eng = fresh_engine()
        scs = ops.make_colstore(s, ["A1", "A2"])
        rcs = ops.make_colstore(r, ["A2", "A3"])
        g = TableGeometry.from_schema(s.schema, ["A1", "A2"], s.row_count)
        ratio = bytes_moved(g)["row_wise"] / max(bytes_moved(g)["rme"], 1)
        us = timeit(lambda: ops.q5_hash_join(eng, s, r).matched, iters=3)
        emit(f"fig12/r{row_bytes:03d}_rme", us, f"bytes_ratio={ratio:.1f}")
        us = timeit(lambda: ops.q5_hash_join(eng, s, r, path="row",
                                             s_colstore=scs, r_colstore=rcs
                                             ).matched, iters=3)
        emit(f"fig12/r{row_bytes:03d}_row", us, "")
        if row_bytes == 64:
            # build-side index cache: re-sorting R per probe vs reusing the
            # version-keyed sorted index
            us_cold = timeit(lambda: (ops.clear_join_build_cache(),
                                      ops.q5_hash_join(eng, s, r).matched)[1],
                             iters=3)
            us_warm = timeit(lambda: ops.q5_hash_join(eng, s, r).matched,
                             iters=3)
            emit(f"fig12/r{row_bytes:03d}_rme_build_cold", us_cold, "")
            emit(f"fig12/r{row_bytes:03d}_rme_build_warm", us_warm,
                 f"speedup={us_cold / max(us_warm, 1e-9):.2f}x")
