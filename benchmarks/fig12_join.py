"""Fig. 12: Q5 equi-join — now with the §8 device offload route measured.

The paper's setup: primary-key build side, ~50% of probe rows match.  Two
rme-path routes are compared **on the same engine**:

* ``device-hash-join`` (default) — build side cached as device hash buckets
  (one build+upload per build-table version), probe offloaded to the
  engine-side grid pass; only the join result exists above the engine.
* ``shared-scan-join`` — the paper's §6 sort-probe: RME slims both sides to
  {key, payload}, ships the packed columns up the hierarchy, and the CPU
  joins "once good locality has been achieved".

``*_route_bytes`` rows report both routes' total data movement
(``bytes_from_dram + bytes_to_cpu + bytes_uploaded`` over a warm-resident
row store with cold derived caches) — the device route must win even at
projectivity 1.0, where the rme scan savings vanish and only the offload
keeps the probe columns from crossing toward the CPU.  ``build_cold`` /
``build_warm`` rows measure the per-version partition build against the
version-keyed cache, and ``snapshot`` runs a MVCC-pinned join through the
``QueryServer`` write path (the route that used to raise ``PlanError``).
"""

import numpy as np

from repro.core import (
    CompileOptions,
    RelationalTable,
    TableGeometry,
    benchmark_schema,
    bytes_moved,
    compile_plan,
    plan,
)
from repro.core import operators as ops
from repro.serve import QueryServer

from .common import bench_rows, emit, fresh_engine, timeit

N_S, N_R = 20_000, 4_096


def make_tables(row_bytes: int):
    rng = np.random.default_rng(0)
    n_s, n_r = bench_rows(N_S), bench_rows(N_R, cap=512)
    schema = benchmark_schema(row_bytes, 4)
    s_cols = {c.name: rng.integers(-1000, 1000, n_s).astype(np.int32)
              for c in schema.columns}
    s_cols["A2"] = rng.integers(0, 2 * n_r, n_s).astype(np.int32)  # ~50% match
    r_cols = {c.name: rng.integers(-1000, 1000, n_r).astype(np.int32)
              for c in schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)  # primary key
    return (RelationalTable.from_columns(schema, s_cols),
            RelationalTable.from_columns(schema, r_cols))


def _route_bytes(eng, q, route: str) -> int:
    """Total movement for one cold-cache execution of ``q`` on ``route``:
    row-store bus beats + bytes shipped up the hierarchy + host→device
    uploads.  The row store stays resident (it mirrors DRAM, not derived
    state); the reorg/build caches are cleared so both routes pay their own
    build."""
    ops.clear_join_build_cache()
    eng.cache.reset()
    eng.stats.reset()
    compile_plan(q, eng, options=CompileOptions(join_route=route)).run()
    st = eng.stats
    return st.bytes_from_dram + st.bytes_to_cpu + st.bytes_uploaded


def _emit_route_bytes(name: str, s, r, projectivity: float) -> None:
    eng = fresh_engine()
    q = plan(s).join(r, key="A2", left_proj="A1",
                     right_proj="A3" if "A3" in r.schema.names else "A1")
    eng.device_words(s)  # warm-resident row stores on both sides
    eng.device_words(r)
    dev = _route_bytes(eng, q, "device-hash-join")
    host = _route_bytes(eng, q, "shared-scan-join")
    emit(name, 0.0,
         f"projectivity={projectivity:.2f},device_bytes={dev},"
         f"host_bytes={host},bytes_ratio={host / max(dev, 1):.2f}")


def run() -> None:
    for row_bytes in (32, 64, 128, 256):
        s, r = make_tables(row_bytes)
        eng = fresh_engine()
        scs = ops.make_colstore(s, ["A1", "A2"])
        rcs = ops.make_colstore(r, ["A2", "A3"])
        g = TableGeometry.from_schema(s.schema, ["A1", "A2"], s.row_count)
        ratio = bytes_moved(g)["row_wise"] / max(bytes_moved(g)["rme"], 1)
        us = timeit(lambda: ops.q5_hash_join(eng, s, r).matched, iters=3)
        emit(f"fig12/r{row_bytes:03d}_rme", us, f"bytes_ratio={ratio:.1f}")
        us = timeit(lambda: ops.q5_hash_join(eng, s, r, path="row",
                                             s_colstore=scs, r_colstore=rcs
                                             ).matched, iters=3)
        emit(f"fig12/r{row_bytes:03d}_row", us, "")
        _emit_route_bytes(f"fig12/r{row_bytes:03d}_route_bytes", s, r,
                          projectivity=8 / row_bytes)
        if row_bytes == 64:
            # partition cache: hash-partitioning R per probe vs reusing the
            # version-keyed device buckets
            us_cold = timeit(lambda: (ops.clear_join_build_cache(),
                                      ops.q5_hash_join(eng, s, r).matched)[1],
                             iters=3)
            us_warm = timeit(lambda: ops.q5_hash_join(eng, s, r).matched,
                             iters=3)
            emit(f"fig12/r{row_bytes:03d}_rme_build_cold", us_cold, "")
            emit(f"fig12/r{row_bytes:03d}_rme_build_warm", us_warm,
                 f"speedup={us_cold / max(us_warm, 1e-9):.2f}x")

    # projectivity 1.0: the join touches every probe byte ({A1, A2} of an
    # 8-byte row) — the acceptance regime where only the offload can win
    s1, r1 = make_tables(8)
    _emit_route_bytes("fig12/proj100_route_bytes", s1, r1, projectivity=1.0)

    # MVCC-pinned join through the server write path (used to PlanError):
    # delete a slice of probe rows, then serve the join from the post-write
    # tick snapshot
    s, r = make_tables(64)
    eng = fresh_engine()
    server = QueryServer(eng)
    n_dead = max(s.row_count // 100, 1)

    def snapshot_join():
        ops.clear_join_build_cache()
        server.submit_delete(s, np.arange(n_dead))
        tk = server.submit(plan(s).join(r, key="A2", left_proj="A1",
                                        right_proj="A3"))
        server.run_tick()
        return tk.result(timeout=120).matched

    us = timeit(snapshot_join, iters=3)
    emit("fig12/r064_snapshot_join", us, "route=device-hash-join")
