"""Table 2 analogue: the RME's on-chip memory budget.

The paper reports FPGA area (BRAM 60.7% — the 2 MB SPMs dominate).  The TPU
adaptation's equivalent scarce resource is VMEM (~128 MB/core on v5e): we
report the modeled VMEM working set of each kernel revision across block
sizes, and the fraction of VMEM it occupies — the quantity that decides
whether the engine's tiles double-buffer cleanly.
"""

from repro.core import TableGeometry, benchmark_schema
from repro.kernels.rme_project import vmem_footprint_bytes

from .common import emit

VMEM_BYTES = 128 << 20  # v5e per-core VMEM


def run() -> None:
    schema = benchmark_schema(64, 4)
    geom = TableGeometry.from_schema(schema, ["A1", "A7", "A13"], 1 << 20)
    for rev in ("bsl", "pck", "mlp"):
        for block_rows in (256, 1024, 4096, 16384):
            b = vmem_footprint_bytes(geom, block_rows, rev)
            emit(
                f"table2/{rev}_block{block_rows}",
                0.0,  # structural metric, no wall time
                f"vmem_bytes={b},vmem_frac={b / VMEM_BYTES:.4f}",
            )
