"""Pipelined serving: express-lane tail latency + SLO counters under load.

The serving-loop claim this figure gates: under **mixed load** — a backlog
of bulk analytics (packed projections over the Q0–Q5 column groups) with
point reads (fused aggregates) arriving behind it — the priority-laned,
pipelined server keeps point-read tail latency bounded by its own work,
while the serial single-lane FIFO makes every point read wait for the
analytics backlog ahead of it.

Two timed configurations on identical workloads and fresh engines:

* ``serial``    — ``QueryServer(lanes=False, pipeline=False)``: the strictly
  serial admit → compile → pass → finalize tick that predates the pipelined
  loop.  Point reads queue behind every bulk projection submitted first.
* ``pipelined`` — the default server: express tickets drain ahead of the
  bulk backlog each tick (still fusing into the tick's one shared pass) and
  ticks are double-buffered, so tick N+1's drain/compile overlaps tick N's
  in-flight device work.

Reported per configuration: wall time of the whole mixed batch, ``qps``,
and nearest-rank latency percentiles split by traffic class
(``express_p50_ms``/``express_p99_ms``/``bulk_p99_ms`` — computed from the
same submitted tickets on both sides, so the serial run's "express" tickets
are the point reads even though it has no lanes).  ``express_speedup`` is
serial express-p99 over pipelined express-p99 — the acceptance metric (≥5x
under mixed load).  All latency-derived values are wall-clock and gate as
WARN-only; the SLO rows below are deterministic and hard-fail:

* ``fig_serving/slo``    — ``deadline_misses`` / ``shed`` / ``degraded``
  from exact-count scenarios (K expired deadlines, K over-bound submits).
* ``fig_serving/stream`` — chunk count and exact result bytes of a streamed
  projection (``stream_chunk_rows`` slicing ⇒ a fixed chunk count at a
  fixed row count).
"""

import math
import time

import numpy as np

from repro.core import plan
from repro.serve import QueryServer, ServerOverloaded

from .common import bench_rows, emit, fresh_engine, make_benchmark_table

N_ROWS = 200_000
N_BULK = 40  # analytics backlog submitted first (10 ticks' worth)
N_EXPRESS = 4  # point reads arriving behind it (one express tick's worth)
MAX_BATCH = 4  # small ticks: the backlog spans several ticks either way
STREAM_CHUNK_ROWS = 256

VIEW_GROUPS = (
    ("A1", "A2", "A3", "A4"),
    ("A1", "A3"),
    ("A2", "A4"),
    ("A1", "A2", "A3"),
    ("A5", "A9"),
    ("A2", "A6", "A7"),
)


def _pct(vals, q: float) -> float:
    s = sorted(vals)
    return s[min(len(s) - 1, max(0, math.ceil(q / 100 * len(s)) - 1))]


def _mixed_round(server, t):
    """Submit the mixed batch bulk-first (the adversarial order for a FIFO)
    and drain; returns (wall_us, express_latencies_s, bulk_latencies_s)."""
    t0 = time.perf_counter()
    bulk = [
        server.submit(plan(t).project(*VIEW_GROUPS[i % len(VIEW_GROUPS)]),
                      client="analytics")
        for i in range(N_BULK)
    ]
    express = [
        server.submit(plan(t).filter("A4", "gt", i % 7).sum("A2"),
                      client="point")
        for i in range(N_EXPRESS)
    ]
    server.drain()
    wall_us = (time.perf_counter() - t0) * 1e6
    for tk in bulk + express:
        tk.result(timeout=300)
    return (wall_us,
            [tk.latency_s for tk in express],
            [tk.latency_s for tk in bulk])


def run() -> None:
    # a taller smoke cap than the default 2k: the figure measures queue-order
    # effects, which only separate from fixed per-tick overhead once a bulk
    # tick's scans carry real weight (still ~seconds in smoke)
    n = bench_rows(N_ROWS, cap=10_000)
    t = make_benchmark_table(n_rows=n)
    total = N_BULK + N_EXPRESS

    walls, pcts = {}, {}
    for mode in ("serial", "pipelined"):
        server = QueryServer(
            fresh_engine(), max_batch=MAX_BATCH,
            lanes=(mode == "pipelined"), pipeline=(mode == "pipelined"),
        )
        # warm the traces (first-compile cost would swamp the queue-order
        # effect being measured), then reset the reorg cache so the measured
        # round's scans run cold — the same protocol both modes
        _mixed_round(server, t)
        server.engine.cache.reset()
        wall_us, exp_lat, bulk_lat = _mixed_round(server, t)
        walls[mode] = wall_us
        pcts[mode] = {
            "express_p50_ms": _pct(exp_lat, 50) * 1e3,
            "express_p99_ms": _pct(exp_lat, 99) * 1e3,
            "bulk_p99_ms": _pct(bulk_lat, 99) * 1e3,
        }

    for mode in ("serial", "pipelined"):
        p = pcts[mode]
        d = (f"queries={total},qps={total / (walls[mode] / 1e6):.0f},"
             f"express_p50_ms={p['express_p50_ms']:.2f},"
             f"express_p99_ms={p['express_p99_ms']:.2f},"
             f"bulk_p99_ms={p['bulk_p99_ms']:.2f}")
        if mode == "pipelined":
            d += (f",express_speedup="
                  f"{pcts['serial']['express_p99_ms'] / max(p['express_p99_ms'], 1e-9):.1f}x"
                  f",speedup={walls['serial'] / max(walls['pipelined'], 1e-9):.2f}x")
        emit(f"fig_serving/{mode}_mixed", walls[mode], d)

    # ---- deterministic SLO counters -------------------------------------
    slo = QueryServer(fresh_engine(), max_queue=8)
    for i in range(3):  # already-expired deadlines: exactly 3 typed misses
        slo.submit(plan(t).project("A1"), deadline_s=0.0)
    for i in range(8 - slo.queue_depth):  # fill to the admission bound
        slo.submit(plan(t).sum("A1"))
    for _ in range(2):  # exactly 2 refusals over the bound
        try:
            slo.submit(plan(t).project("A2"))
        except ServerOverloaded:
            pass
    slo.drain()
    deg = QueryServer(fresh_engine(), max_queue=2, overload="degrade")
    for i in range(4):  # 2 admitted, 2 demoted to bulk (the soft bound)
        deg.submit(plan(t).sum("A1"))
    deg.drain()
    emit("fig_serving/slo", 0.0,
         f"deadline_misses={slo.stats.deadline_misses},"
         f"shed={slo.stats.shed},degraded={deg.stats.degraded}")

    # ---- deterministic streaming shape ----------------------------------
    st = QueryServer(fresh_engine())
    tk = st.submit(plan(t).project("A1", "A2"), stream=True,
                   stream_chunk_rows=STREAM_CHUNK_ROWS)
    st.drain()
    chunks = list(tk.chunks(timeout=30))
    stream_bytes = int(sum(np.asarray(c).nbytes for c in chunks))
    emit("fig_serving/stream", 0.0,
         f"rows={n},stream_chunks={len(chunks)},"
         f"stream_result_bytes={stream_bytes}")
