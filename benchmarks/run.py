"""Benchmark driver: one module per paper table/figure + the LM step bench.

Prints ``name,us_per_call,derived`` CSV rows (and a trailing summary), the
format consumed by EXPERIMENTS.md.  ``python -m benchmarks.run [pattern]``
runs the subset whose module name contains ``pattern``;
``python -m benchmarks.run --smoke`` runs every figure at smoke scale (tiny
tables, single iterations) — the CI job that catches kernel-lowering
regressions without paying for real measurements.

``--json PATH`` additionally writes the results machine-readably: every row's
name, wall time, and parsed ``derived`` key=value fields (bytes moved,
throughput, latency percentiles, ...), so perf can be diffed across PRs
(``benchmarks/run.py --json BENCH_pr3.json`` then compare files).

``--update-baselines`` refreshes the committed perf-gate baseline
(``benchmarks/baselines/smoke.json`` for ``--smoke``, ``full.json``
otherwise) — run it after an intentional perf change, commit the diff, and
the CI ``perf-gate`` job compares every future run against it
(``python -m benchmarks.perf_gate``).  ``--rows N`` caps every figure's
table size without smoke-mode shortcuts (the nightly job's 50k regime).
"""

import argparse
import json
import pathlib
import time

from . import (
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_compression,
    fig_concurrent_queries,
    fig_dist_scaling,
    fig_fault_recovery,
    fig_htap_ingest,
    fig_mixed_batch,
    fig_optimizer,
    fig_scan_sharing,
    fig_selectivity,
    fig_serving_pipeline,
    table2_vmem_budget,
    lm_step,
)
from .common import flush_rows, set_row_cap, set_smoke

BASELINE_DIR = pathlib.Path(__file__).parent / "baselines"

MODULES = [
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_compression,
    fig_concurrent_queries,
    fig_dist_scaling,
    fig_fault_recovery,
    fig_htap_ingest,
    fig_mixed_batch,
    fig_optimizer,
    fig_scan_sharing,
    fig_selectivity,
    fig_serving_pipeline,
    table2_vmem_budget,
    lm_step,
]


def _parse_derived(derived: str) -> dict:
    """``k1=v1,k2=v2`` -> dict with numbers decoded (non-kv text kept raw)."""
    out: dict = {}
    for part in derived.split(","):
        if "=" not in part:
            if part:
                out.setdefault("notes", []).append(part)
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v.rstrip("x"))
            except ValueError:
                out[k] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pattern", nargs="?", default="",
                    help="run only modules whose name contains this substring")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny row counts + single iterations (CI regression probe)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as JSON for cross-PR perf diffing")
    ap.add_argument("--rows", type=int, default=None, metavar="N",
                    help="cap every figure's table size (nightly: 50000)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="write the report to benchmarks/baselines/ — the "
                         "committed reference the CI perf-gate compares against")
    args = ap.parse_args()
    if args.smoke:
        set_smoke(True)
    if args.rows is not None:
        set_row_cap(args.rows)
    print("name,us_per_call,derived")
    t0 = time.time()
    rows = []
    for mod in MODULES:
        if args.pattern and args.pattern not in mod.__name__:
            continue
        mod.run()
        rows.extend(flush_rows())
    elapsed = time.time() - t0
    print(f"# {len(rows)} rows in {elapsed:.1f}s")
    report = {
        "smoke": args.smoke,
        "pattern": args.pattern,
        "elapsed_s": round(elapsed, 3),
        "rows": [
            {"name": name, "us_per_call": us, "derived": _parse_derived(d)}
            for name, us, d in rows
        ],
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {args.json}")
    if args.update_baselines:
        if args.pattern:
            raise SystemExit("--update-baselines needs a full run (no pattern)")
        BASELINE_DIR.mkdir(exist_ok=True)
        path = BASELINE_DIR / ("smoke.json" if args.smoke else "full.json")
        with open(path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote baseline {path}")


if __name__ == "__main__":
    main()
