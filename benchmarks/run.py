"""Benchmark driver: one module per paper table/figure + the LM step bench.

Prints ``name,us_per_call,derived`` CSV rows (and a trailing summary), the
format consumed by EXPERIMENTS.md.  ``python -m benchmarks.run [pattern]``
runs the subset whose module name contains ``pattern``.
"""

import sys
import time

from . import (
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_scan_sharing,
    fig_selectivity,
    table2_vmem_budget,
    lm_step,
)
from .common import flush_rows

MODULES = [
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_scan_sharing,
    fig_selectivity,
    table2_vmem_budget,
    lm_step,
]


def main() -> None:
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    t0 = time.time()
    total = 0
    for mod in MODULES:
        if pattern and pattern not in mod.__name__:
            continue
        mod.run()
        total += len(flush_rows())
    print(f"# {total} rows in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
