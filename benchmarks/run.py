"""Benchmark driver: one module per paper table/figure + the LM step bench.

Prints ``name,us_per_call,derived`` CSV rows (and a trailing summary), the
format consumed by EXPERIMENTS.md.  ``python -m benchmarks.run [pattern]``
runs the subset whose module name contains ``pattern``;
``python -m benchmarks.run --smoke`` runs every figure at smoke scale (tiny
tables, single iterations) — the CI job that catches kernel-lowering
regressions without paying for real measurements.
"""

import argparse
import time

from . import (
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_concurrent_queries,
    fig_scan_sharing,
    fig_selectivity,
    table2_vmem_budget,
    lm_step,
)
from .common import flush_rows, set_smoke

MODULES = [
    fig6_offset_revisions,
    fig7_q1_colwidth,
    fig9_projectivity,
    fig10_queries_colsize,
    fig11_queries_rowsize,
    fig12_join,
    fig13_scaling,
    fig_concurrent_queries,
    fig_scan_sharing,
    fig_selectivity,
    table2_vmem_budget,
    lm_step,
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pattern", nargs="?", default="",
                    help="run only modules whose name contains this substring")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny row counts + single iterations (CI regression probe)")
    args = ap.parse_args()
    if args.smoke:
        set_smoke(True)
    print("name,us_per_call,derived")
    t0 = time.time()
    total = 0
    for mod in MODULES:
        if args.pattern and args.pattern not in mod.__name__:
            continue
        mod.run()
        total += len(flush_rows())
    print(f"# {total} rows in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
