"""Fig. 10: Q2 (select+project), Q3 (select+aggregate), Q4 (group-by) with
varying column size at fixed 64B rows — RME fused kernels vs direct row-wise.
"""

from repro.core import operators as ops

from .common import bench_rows, emit, fresh_engine, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    for col_bytes in (4, 8, 16):
        n_cols = 64 // col_bytes
        t = make_benchmark_table(row_bytes=64, col_bytes=4,
                                 n_rows=bench_rows(N_ROWS))
        eng = fresh_engine()
        cs = ops.make_colstore(t, list(t.schema.names))

        us = timeit(lambda: ops.q2_select_project(eng, t, "A1", "A3", 100),
                    iters=3)
        emit(f"fig10/q2_c{col_bytes:02d}_rme", us, f"sel~90%,cols={n_cols}")
        us = timeit(lambda: ops.q2_select_project(eng, t, "A1", "A3", 100,
                                                  path="row", colstore=cs), iters=3)
        emit(f"fig10/q2_c{col_bytes:02d}_row", us, "")

        us = timeit(lambda: ops.q3_select_aggregate(eng, t, "A2", "A4", -800),
                    iters=3)
        emit(f"fig10/q3_c{col_bytes:02d}_rme", us, "sel~10%")
        us = timeit(lambda: ops.q3_select_aggregate(eng, t, "A2", "A4", -800,
                                                    path="row", colstore=cs), iters=3)
        emit(f"fig10/q3_c{col_bytes:02d}_row", us, "")

        us = timeit(lambda: ops.q4_groupby_avg(eng, t, "A1", "A3", "A2", -800, 64),
                    iters=3)
        emit(f"fig10/q4_c{col_bytes:02d}_rme", us, "groups=64")
        us = timeit(lambda: ops.q4_groupby_avg(eng, t, "A1", "A3", "A2", -800, 64,
                                               path="row", colstore=cs), iters=3)
        emit(f"fig10/q4_c{col_bytes:02d}_row", us, "")
