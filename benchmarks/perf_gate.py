"""Perf-regression gate: diff a benchmark JSON report against a baseline.

CI runs ``python -m benchmarks.run --smoke --json bench.json`` and then::

    python -m benchmarks.perf_gate benchmarks/baselines/smoke.json bench.json

The gate walks every baseline row's *derived* metrics (the parsed ``k=v``
fields — byte counts, ratios, throughput) and fails when any metric
regresses beyond tolerance in its bad direction.  Two metric classes:

* **deterministic** — PMU byte counts, accounting ratios, scan/upload
  counts.  These are exact outputs of the engine's charging rules, so any
  drift beyond the tolerance means the accounting (or the datapath behind
  it) changed; the default 25% headroom only absorbs benign row-count
  rounding between environments.
* **wall-derived** (``speedup``/``qps``/``tok_per_s``/…) — smoke mode times
  a single iteration, and back-to-back runs on one machine were measured
  swinging a serving-tick qps by 6x, so these cannot fail the gate by
  default: violations beyond ``tolerance × noise-factor`` (default 3x ⇒
  75%) are printed as warnings for a human to read.  ``--strict-noisy``
  escalates them to failures (useful on a quiet dedicated runner).  Per-lane
  latency percentiles (any ``*_ms`` metric, e.g. ``express_p99_ms``) gate
  the same way — lower-better, warn-only — except the legacy ``p50_ms``/
  ``p95_ms`` keys, which stay skipped.

Unknown metric names and non-numeric fields are skipped; a baseline row
missing from the current report fails (a figure silently disappearing is a
regression).  After an *intentional* perf change, refresh the baseline with
``python -m benchmarks.run --smoke --json /dev/null --update-baselines``
and commit the diff — the gate is a ratchet, not an aspiration.
"""

from __future__ import annotations

import argparse
import json
import sys

# Deterministic accounting metrics: exact outputs of the PMU charging rules.
HIGHER_BETTER = {
    "bytes_ratio", "shared_ratio", "bytes_saved", "saving", "seed_vs_batch",
    "upload_ratio", "delta_hits",
    # a streamed projection collapsing to fewer chunks means incremental
    # delivery regressed (the count is exact at a fixed row count)
    "stream_chunks",
    # fault scenarios construct an exact ticket count: serving fewer means
    # a recovery path started failing tickets it used to save
    "served",
    # the optimizer figure's covering batch subsumes an exact request
    # count: fewer means scan-sharing detection regressed
    "subsumed",
}
LOWER_BETTER = {
    "device_bytes", "host_bytes", "solo_bytes", "served_bytes", "batch_bytes",
    "seed_bytes", "masked_bytes", "compact_bytes", "beats_bytes", "rme_bytes",
    "row_bytes", "col_bytes", "union_rme_bytes", "uploaded", "uploaded_delta",
    "uploads_first", "uploads_now", "uploads_seed", "uploads_solo",
    "uploads_batch", "one_pass_scans", "vmem_bytes", "vmem_frac",
    "collective_ops",
    # SLO counters from exact-count scenarios: more misses/refusals than the
    # scenario constructs means admission control or deadline logic drifted
    "deadline_misses", "shed", "degraded",
    # fault-recovery counters (fig_fault_recovery): each scenario injects an
    # exact fault schedule, so burning more retries/failovers/trips than it
    # constructs means the recovery ladder drifted (e.g. a transient now
    # escalates to failover, or the breaker trips on healthy routes)
    "retries", "failovers", "poisoned", "quarantined",
    "breaker_trips", "breaker_fallbacks", "breaker_open", "wal_records",
}
# Wall-clock-derived metrics: direction known, but smoke noise is real.
NOISY_HIGHER = {"speedup", "qps", "tok_per_s", "express_speedup"}
NOISY_LOWER = {"norm_vs_row"}
# Workload parameters (not measurements) and raw single-iteration latency
# percentiles (pure scheduler noise at smoke scale — the qps/speedup ratios
# gate the same path with run-relative normalization).
SKIP = {
    "k", "rows", "cols", "clients", "groups", "queries", "rounds", "views",
    "writes", "tile", "projectivity", "notes", "p50_ms", "p95_ms", "shards",
    # gated by fig_fault_recovery's own in-module ≤5% hard assert; the
    # relative-regression math degenerates on its ~0 baseline
    "overhead_pct",
}


def classify(key: str) -> tuple[str, bool] | None:
    """(bad direction, noisy) for a metric, or None to skip.

    ``"down"`` means a *decrease* is a regression (higher is better);
    ``"up"`` means an increase is.  Unknown ``*_bytes`` keys default to
    deterministic lower-better so new byte metrics are gated from day one.
    """
    if key in SKIP:
        return None
    if key in HIGHER_BETTER:
        return "down", False
    if key in LOWER_BETTER:
        return "up", False
    if key in NOISY_HIGHER:
        return "down", True
    if key in NOISY_LOWER:
        return "up", True
    if key.endswith("_ms"):
        # per-lane latency percentiles (express_p99_ms, ...): wall-derived,
        # lower is better — gated as warnings like qps/speedup, so the tail
        # is watched without smoke-scheduler noise failing CI
        return "up", True
    if key.endswith("_bytes"):
        return "up", False
    return None


def regression(base: float, cur: float, bad: str) -> float:
    """Relative change in the bad direction (0 when improved or flat)."""
    if base == 0:
        return 0.0 if cur == 0 else float("inf") if bad == "up" else 0.0
    delta = (cur - base) / abs(base)
    return max(0.0, delta if bad == "up" else -delta)


def gate(baseline: dict, current: dict, tolerance: float,
         noise_factor: float) -> tuple[list[str], list[str]]:
    """(failures, warnings): deterministic-metric violations and missing
    rows fail; wall-derived violations warn (escalated by --strict-noisy)."""
    cur_rows = {row["name"]: row for row in current["rows"]}
    failures: list[str] = []
    warnings: list[str] = []
    for row in baseline["rows"]:
        name = row["name"]
        cur = cur_rows.get(name)
        if cur is None:
            failures.append(f"{name}: row missing from current report")
            continue
        for key, base_val in row["derived"].items():
            if not isinstance(base_val, (int, float)):
                continue
            cls = classify(key)
            if cls is None:
                continue
            cur_val = cur["derived"].get(key)
            if not isinstance(cur_val, (int, float)):
                failures.append(f"{name}: metric {key} missing from current")
                continue
            bad, noisy = cls
            allowed = tolerance * (noise_factor if noisy else 1.0)
            reg = regression(float(base_val), float(cur_val), bad)
            if reg > allowed:
                msg = (
                    f"{name}: {key} regressed {reg:.0%} "
                    f"(baseline {base_val}, current {cur_val}, "
                    f"allowed {allowed:.0%}{' noisy' if noisy else ''})"
                )
                (warnings if noisy else failures).append(msg)
    return failures, warnings


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("current", help="freshly generated report JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed regression for deterministic metrics")
    ap.add_argument("--noise-factor", type=float, default=3.0,
                    help="warning threshold multiplier for wall-derived metrics")
    ap.add_argument("--strict-noisy", action="store_true",
                    help="escalate wall-derived violations from warnings to "
                         "failures (quiet dedicated runners only)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures, warnings = gate(baseline, current, args.tolerance,
                              args.noise_factor)
    if args.strict_noisy:
        failures, warnings = failures + warnings, []
    checked = sum(
        1
        for row in baseline["rows"]
        for k, v in row["derived"].items()
        if isinstance(v, (int, float)) and classify(k) is not None
    )
    for w in warnings:
        print(f"  WARN {w}")
    if failures:
        print(f"perf-gate: {len(failures)} regression(s) over "
              f"{checked} gated metrics:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        print("(intentional change? refresh with "
              "`python -m benchmarks.run --smoke --update-baselines`)")
        sys.exit(1)
    print(f"perf-gate: OK — {checked} metrics within tolerance "
          f"({args.tolerance:.0%}), {len(warnings)} noisy warning(s)")


if __name__ == "__main__":
    main()
