"""Fig. 6: hardware revisions (BSL/PCK/MLP) × column offset, Q0 aggregate.

Reproduces the paper's two findings: (1) progressive improvement from the
revisions with MLP ≈ the production datapath, hot accesses identical across
revisions; (2) latency is insensitive to the projected column's offset, with
burst-length spikes only where the column straddles a bus line (our word-
aligned adaptation: an 8-byte column at offset ≡ 12 mod 16).
"""

import jax.numpy as jnp

from repro.core import TableGeometry, bytes_moved
from repro.kernels.ops import project_any

from .common import bench_rows, emit, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    t = make_benchmark_table(n_rows=n_rows)
    words = jnp.asarray(t.words()[:, : t.schema.row_words])

    # --- revision sweep (cold = projection kernel; hot = cached read + sum)
    geom = TableGeometry.from_schema(t.schema, ["A1"], n_rows)
    for rev in ("bsl", "pck", "mlp", "xla"):
        us = timeit(lambda: jnp.sum(
            project_any(words, geom, revision=rev, block_rows=2048)
        ), iters=3)
        emit(f"fig6/q0_cold_{rev}", us, f"beats_bytes={bytes_moved(geom)['rme']}")
    packed = project_any(words, geom, revision="xla")
    emit("fig6/q0_hot", timeit(lambda: jnp.sum(packed)), "cached_view")
    full = words  # direct row-wise: ships every row word
    emit("fig6/q0_direct_row", timeit(lambda: jnp.sum(full[:, 0])),
         f"row_bytes={n_rows * 64}")

    # --- offset sweep (8-byte column; spike expected at offset%16 == 12)
    base_beats = None
    for off_w in range(0, 14, 1):
        geom = TableGeometry(
            row_bytes=64, row_count=n_rows, col_widths=(8,),
            col_rel_offsets=(off_w * 4,),
        )
        us = timeit(lambda g=geom: jnp.sum(
            project_any(words, g, revision="xla")
        ), iters=3)
        beats = bytes_moved(geom)["rme"]
        if base_beats is None:
            base_beats = beats
        emit(f"fig6/offset_{off_w * 4:02d}B", us,
             f"rme_bytes={beats},spike={'yes' if beats > base_beats else 'no'}")
