"""HTAP ingest: sustained writes + concurrent analytics, delta vs re-upload.

The write-path acceptance figure.  One resident relation takes a sustained
OLTP stream — every round the QueryServer admits an insert batch, a small
update, and a small delete interleaved with a mixed analytical read set
(sum, filtered avg, group-by, projection), all served with per-tick snapshot
reads.  Two engines run the *identical* workload:

* ``delta``    — the delta-chunked device row store (default engine):
  appends ship as tail chunks, deletes/updates ship only patched ``__ts_end``
  words, hot views survive appends via incremental tail scans.
* ``reupload`` — ``delta_uploads=False``: any table change re-ships the
  whole word buffer on next access (the pre-delta behavior).

Reported per side: wall time, served-query throughput, host→device bytes
(total and delta-only), and the headline ``upload_ratio`` — reupload bytes /
delta bytes, which the acceptance criterion pins at ≥ 5x (it grows with
table size: O(rounds·T) vs O(rounds·delta)).
"""

import time

import numpy as np

from repro.core import RelationalMemoryEngine, plan
from repro.serve import QueryServer

from .common import bench_rows, emit, make_benchmark_table

N_ROWS = 50_000
ROUNDS = 8
APPEND_ROWS = 64
UPDATE_ROWS = 8
DELETE_ROWS = 4


def _run_side(delta: bool, n_rows: int, rounds: int) -> dict:
    t = make_benchmark_table(n_rows=n_rows)
    schema = t.schema
    eng = RelationalMemoryEngine(revision="xla", delta_uploads=delta)
    server = QueryServer(eng, snapshot_reads=True)
    _ = eng.aggregate(t, "A1")  # make the table device-resident
    _ = eng.register(t, ("A1", "A2")).packed()  # ...and one view hot
    eng.stats.reset()  # measure steady-state ingest, not the initial load

    rng = np.random.default_rng(7)
    served = 0
    t0 = time.perf_counter()
    for r in range(rounds):
        fresh = {c.name: rng.integers(-1000, 1000, APPEND_ROWS).astype(np.int32)
                 for c in schema.columns}
        server.submit_insert(t, fresh, client="ingest")
        upd_rows = rng.integers(0, n_rows, UPDATE_ROWS)
        server.submit_update(
            t, upd_rows,
            {"A2": rng.integers(-1000, 1000, UPDATE_ROWS).astype(np.int32)},
            client="ingest",
        )
        server.submit_delete(t, rng.integers(0, n_rows, DELETE_ROWS),
                             client="ingest")
        tickets = [
            server.submit(plan(t).sum("A1"), client="analyst"),
            server.submit(plan(t).filter("A3", "gt", 0).avg("A2"),
                          client="analyst"),
            server.submit(plan(t).groupby("A4", "A1", "avg", 16),
                          client="analyst"),
            server.submit(plan(t).project("A1", "A2"), client="analyst"),
        ]
        server.run_tick()
        for tk in tickets:
            tk.result(timeout=120)
        served += len(tickets)
        # the dashboard's standing view, re-read every round: the delta
        # engine extends it with a tail scan (incremental view maintenance,
        # counted in delta_hits); the baseline re-projects all T rows
        _ = eng.register(t, ("A1", "A2")).packed()
        served += 1
    dt = time.perf_counter() - t0
    return {
        "wall_s": dt,
        "qps": served / max(dt, 1e-9),
        "uploaded": eng.stats.bytes_uploaded,
        "uploaded_delta": eng.stats.bytes_uploaded_delta,
        "delta_hits": eng.stats.delta_hits,
        "writes": server.stats.writes_applied,
    }


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    rounds = 2 if n_rows < N_ROWS else ROUNDS

    d = _run_side(delta=True, n_rows=n_rows, rounds=rounds)
    f = _run_side(delta=False, n_rows=n_rows, rounds=rounds)
    ratio = f["uploaded"] / max(d["uploaded"], 1)
    emit(
        "fig_htap_ingest/delta", d["wall_s"] * 1e6,
        f"rows={n_rows},rounds={rounds},uploaded={d['uploaded']},"
        f"uploaded_delta={d['uploaded_delta']},delta_hits={d['delta_hits']},"
        f"writes={d['writes']},qps={d['qps']:.0f}",
    )
    emit(
        "fig_htap_ingest/reupload", f["wall_s"] * 1e6,
        f"rows={n_rows},rounds={rounds},uploaded={f['uploaded']},"
        f"qps={f['qps']:.0f},upload_ratio={ratio:.1f}x,"
        f"speedup={f['wall_s'] / max(d['wall_s'], 1e-9):.2f}x",
    )
