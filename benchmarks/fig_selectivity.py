"""Beyond-paper figure: in-engine selection compaction vs selectivity.

The paper's §8 names selection as the next operator to push into hardware;
`rme_select.select_compact` implements it (block compaction + fill counts).
This benchmark sweeps predicate selectivity and reports the bytes a consumer
receives per path — the compaction payoff the mask-based Q2 path cannot
give.
"""

import jax.numpy as jnp

from repro.core import TableGeometry
from repro.kernels.rme_select import densify, select_compact

from .common import bench_rows, emit, make_benchmark_table, timeit

N_ROWS = 20_000


def run() -> None:
    n_rows = bench_rows(N_ROWS)
    t = make_benchmark_table(n_rows=n_rows, seed=3)
    geom = TableGeometry.from_schema(t.schema, ["A1", "A9"], n_rows)
    words = jnp.asarray(t.words())
    out_bytes_row = geom.out_bytes_per_row
    for pct, k in ((90, -800), (50, 0), (10, 800), (1, 980)):  # A3 ∈ ±1000
        blocks, counts = select_compact(
            words, geom, pred_word=2, pred_op="gt", pred_k=k, block_rows=512
        )
        n_sel = int(counts.sum())
        us = timeit(lambda: select_compact(
            words, geom, pred_word=2, pred_op="gt", pred_k=k, block_rows=512
        )[1], iters=3)
        shipped = n_sel * out_bytes_row
        masked = n_rows * out_bytes_row  # what the mask-based Q2 path ships
        emit(
            f"fig_sel/sel{pct:02d}pct", us,
            f"rows={n_sel},compact_bytes={shipped},masked_bytes={masked},"
            f"saving={masked / max(shipped, 1):.1f}x",
        )
        _ = densify(blocks, counts, total=max(n_sel, 1))
